//! Durable sampler state: the versioned, checksummed binary snapshot
//! codec (ROADMAP item 5b).
//!
//! A [`Snapshot`] captures the **full** state of one sampler — tree
//! node sums, slot/assignment tables, the live set, the quantized
//! [`ClassStore`], the serving epoch, and the capacity reservation —
//! as plain data ([`SamplerState`]), decoupled from the feature map:
//! maps are cheap to rebuild from config + seed, while the `O(n·D)`
//! tree is exactly what a cold start cannot afford to recompute. A
//! [`map_fingerprint`] (FNV-1a over φ of a deterministic probe vector)
//! is stored alongside so restoring into a skeleton built with the
//! *wrong* map fails with a typed error instead of silently serving a
//! perturbed distribution.
//!
//! ## Wire format (version 1)
//!
//! ```text
//! [ magic  8B "RFSNAP\0\0" ]
//! [ version u32 LE         ]   decoder rejects > SNAPSHOT_VERSION
//! [ epoch   u64 LE         ]   serving epoch at capture
//! [ kind    u8             ]   SamplerState discriminant
//! [ payload ...            ]   kind-specific, length-prefixed fields
//! [ checksum u64 LE        ]   FNV-1a 64 over everything above
//! ```
//!
//! All integers little-endian; `Vec` fields are `u64` length-prefixed.
//! The checksum trailer covers magic through payload, so truncation,
//! bit rot, and version skew each surface as a distinct
//! [`SnapshotError`] — never a panic (corruption tests pin this).
//!
//! **Versioning policy**: the version bumps only on layout changes;
//! decoders must read every version ≤ their own and reject newer ones
//! with [`SnapshotError::FutureVersion`] (forward compatibility is
//! explicitly *not* promised — a snapshot is a warm-start artifact,
//! not an archival format).
//!
//! Snapshots are registered through [`crate::runtime::manifest`] (a
//! `snapshots` section beside the AOT `artifacts`), fetched over the
//! wire via the v3 `STATE_SNAPSHOT` chunked admin frame, and staged
//! into serving through [`crate::serving::SamplerWriter`] so readers
//! never observe partial state. See the crate-level Durability docs.

use crate::featmap::FeatureMap;
use crate::linalg::{ClassStore, Matrix};
use std::fmt;
use std::path::Path;

/// Leading bytes of every snapshot file/stream.
pub const SNAPSHOT_MAGIC: [u8; 8] = *b"RFSNAP\0\0";

/// Current encoder version; decoders accept `1..=SNAPSHOT_VERSION`.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Typed snapshot failures. Decoding never panics: every corruption
/// mode maps to one of these.
#[derive(Clone, Debug, PartialEq)]
pub enum SnapshotError {
    /// The byte stream ended before the declared structure did.
    Truncated,
    /// Leading bytes are not [`SNAPSHOT_MAGIC`].
    BadMagic,
    /// Encoded by a newer build than this decoder understands.
    FutureVersion { found: u32, max: u32 },
    /// FNV-1a trailer mismatch (bit rot or a torn write).
    BadChecksum { stored: u64, computed: u64 },
    /// Structurally invalid payload (lengths/invariants violated).
    Malformed(&'static str),
    /// The restoring sampler's feature map does not reproduce the φ
    /// fingerprint stored at capture time.
    MapMismatch { stored: u64, computed: u64 },
    /// The target sampler kind cannot restore this state (or does not
    /// support snapshots at all).
    Unsupported(&'static str),
    /// Filesystem failure reading/writing the snapshot artifact.
    Io(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Truncated => write!(f, "snapshot truncated"),
            SnapshotError::BadMagic => write!(f, "snapshot: bad magic"),
            SnapshotError::FutureVersion { found, max } => write!(
                f,
                "snapshot version {found} is newer than supported {max}"
            ),
            SnapshotError::BadChecksum { stored, computed } => write!(
                f,
                "snapshot checksum mismatch: stored {stored:#018x}, \
                 computed {computed:#018x}"
            ),
            SnapshotError::Malformed(what) => {
                write!(f, "snapshot malformed: {what}")
            }
            SnapshotError::MapMismatch { stored, computed } => write!(
                f,
                "snapshot feature-map fingerprint mismatch: stored \
                 {stored:#018x}, this map computes {computed:#018x}"
            ),
            SnapshotError::Unsupported(who) => {
                write!(f, "snapshot unsupported by sampler '{who}'")
            }
            SnapshotError::Io(e) => write!(f, "snapshot io: {e}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> Self {
        SnapshotError::Io(e.to_string())
    }
}

/// FNV-1a 64-bit — std-only, streaming-friendly, good enough to catch
/// torn writes and bit rot (not adversarial tampering).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Deterministic probe vector for [`map_fingerprint`]: a fixed
/// xorshift-derived unit vector of dimension `d`, identical on every
/// build and platform (pure integer generation, then one normalize).
pub fn probe_vector(d: usize) -> Vec<f32> {
    let mut x = 0x9e37_79b9_7f4a_7c15u64 ^ (d as u64);
    let mut v: Vec<f32> = (0..d)
        .map(|_| {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            // Map to (-1, 1) via the top 24 bits.
            ((x >> 40) as f32 / 8_388_608.0) - 1.0
        })
        .collect();
    let norm = v.iter().map(|a| (*a as f64) * (*a as f64)).sum::<f64>().sqrt();
    if norm > 0.0 {
        for a in &mut v {
            *a = (*a as f64 / norm) as f32;
        }
    }
    v
}

/// Fingerprint of a feature map: FNV-1a over its dims plus the exact
/// f32 bit patterns of `φ(probe)`. Two maps agree iff they compute the
/// same φ on the probe — which is what restore correctness needs (the
/// tree's sums are sums of this map's φ values).
pub fn map_fingerprint<M: FeatureMap + ?Sized>(map: &M) -> u64 {
    let probe = probe_vector(map.input_dim());
    let phi = map.map(&probe);
    let mut bytes =
        Vec::with_capacity(16 + phi.len() * std::mem::size_of::<f32>());
    bytes.extend_from_slice(&(map.input_dim() as u64).to_le_bytes());
    bytes.extend_from_slice(&(map.output_dim() as u64).to_le_bytes());
    for v in &phi {
        bytes.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fnv1a(&bytes)
}

// ---------------------------------------------------------------------------
// Plain-data state mirrors
// ---------------------------------------------------------------------------

/// Full state of one [`crate::sampler::KernelTree`] (plain data; field
/// semantics match the tree's own documentation).
#[derive(Clone, Debug, PartialEq)]
pub struct TreeState {
    pub dim: usize,
    pub n: usize,
    pub pad: usize,
    pub left_sums: Vec<f32>,
    pub left_live: Vec<u32>,
    pub total: Vec<f32>,
    pub live: usize,
    pub retired: Vec<bool>,
    pub eps: f64,
    pub growths: usize,
}

impl TreeState {
    /// Structural invariants a decoded tree must satisfy before it can
    /// back a live sampler. Every violation is `Malformed`, not a
    /// panic.
    pub fn validate(&self) -> Result<(), SnapshotError> {
        if self.dim == 0 {
            return Err(SnapshotError::Malformed("tree: dim == 0"));
        }
        if self.n == 0 {
            return Err(SnapshotError::Malformed("tree: n == 0"));
        }
        if self.eps <= 0.0 || !self.eps.is_finite() {
            return Err(SnapshotError::Malformed("tree: eps must be > 0"));
        }
        if !self.pad.is_power_of_two() || self.pad < 2 || self.pad < self.n {
            return Err(SnapshotError::Malformed("tree: bad pad"));
        }
        if self.left_sums.len() != (self.pad - 1) * self.dim {
            return Err(SnapshotError::Malformed("tree: left_sums length"));
        }
        if self.left_live.len() != self.pad - 1 {
            return Err(SnapshotError::Malformed("tree: left_live length"));
        }
        if self.total.len() != self.dim {
            return Err(SnapshotError::Malformed("tree: total length"));
        }
        if self.retired.len() != self.n {
            return Err(SnapshotError::Malformed("tree: retired length"));
        }
        let holes = self.retired.iter().filter(|r| **r).count();
        if self.live != self.n - holes {
            return Err(SnapshotError::Malformed(
                "tree: live count disagrees with retired flags",
            ));
        }
        Ok(())
    }
}

/// Quantized class-embedding table state (mirrors
/// [`crate::linalg::ClassStore`]).
#[derive(Clone, Debug, PartialEq)]
pub enum ClassStoreState {
    F32 { cols: usize, data: Vec<f32> },
    F16 { cols: usize, data: Vec<u16> },
    I8 { cols: usize, data: Vec<i8>, scales: Vec<f32> },
}

impl ClassStoreState {
    pub fn cols(&self) -> usize {
        match self {
            ClassStoreState::F32 { cols, .. }
            | ClassStoreState::F16 { cols, .. }
            | ClassStoreState::I8 { cols, .. } => *cols,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            ClassStoreState::F32 { cols, data } => {
                data.len() / (*cols).max(1)
            }
            ClassStoreState::F16 { cols, data } => {
                data.len() / (*cols).max(1)
            }
            ClassStoreState::I8 { scales, .. } => scales.len(),
        }
    }

    pub fn validate(&self) -> Result<(), SnapshotError> {
        let cols = self.cols();
        if cols == 0 {
            return Err(SnapshotError::Malformed("class store: cols == 0"));
        }
        match self {
            ClassStoreState::F32 { data, .. } => {
                if data.len() % cols != 0 {
                    return Err(SnapshotError::Malformed(
                        "class store: f32 data not a whole number of rows",
                    ));
                }
            }
            ClassStoreState::F16 { data, .. } => {
                if data.len() % cols != 0 {
                    return Err(SnapshotError::Malformed(
                        "class store: f16 data not a whole number of rows",
                    ));
                }
            }
            ClassStoreState::I8 { data, scales, .. } => {
                if data.len() != scales.len() * cols {
                    return Err(SnapshotError::Malformed(
                        "class store: i8 data/scales mismatch",
                    ));
                }
            }
        }
        Ok(())
    }

    /// Capture a live [`ClassStore`].
    pub fn capture(store: &ClassStore) -> Self {
        match store {
            ClassStore::F32(m) => ClassStoreState::F32 {
                cols: m.cols(),
                data: m.data().to_vec(),
            },
            ClassStore::F16 { cols, data } => ClassStoreState::F16 {
                cols: *cols,
                data: data.clone(),
            },
            ClassStore::I8 { cols, data, scales } => ClassStoreState::I8 {
                cols: *cols,
                data: data.clone(),
                scales: scales.clone(),
            },
        }
    }

    /// Rebuild a [`ClassStore`] (caller validates first).
    pub fn materialize(&self) -> ClassStore {
        match self {
            ClassStoreState::F32 { cols, data } => ClassStore::F32(
                Matrix::from_vec(data.len() / cols, *cols, data.clone()),
            ),
            ClassStoreState::F16 { cols, data } => {
                ClassStore::F16 { cols: *cols, data: data.clone() }
            }
            ClassStoreState::I8 { cols, data, scales } => ClassStore::I8 {
                cols: *cols,
                data: data.clone(),
                scales: scales.clone(),
            },
        }
    }
}

/// Unsharded kernel sampler state ([`crate::sampler::RffSampler`] /
/// `QuadraticSampler`).
#[derive(Clone, Debug, PartialEq)]
pub struct KernelState {
    pub map_fingerprint: u64,
    pub tree: TreeState,
    pub classes: ClassStoreState,
}

/// Sharded kernel sampler state. `assign` packs the slot table as
/// `shard << 32 | local`, with `u64::MAX` marking a retired hole.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardedState {
    pub map_fingerprint: u64,
    pub shards: Vec<TreeState>,
    pub assign: Vec<u64>,
    pub globals: Vec<Vec<u32>>,
    pub n: usize,
    pub live: usize,
    pub dim: usize,
    pub eps: f64,
    /// Capacity pre-reservation carried through restore so post-restore
    /// growth keeps its zero-doubling guarantee.
    pub reserve: usize,
    pub target_shards: usize,
    pub rebalance_threshold: f64,
    pub classes: ClassStoreState,
}

/// Slot-table sentinel for a retired global id in
/// [`ShardedState::assign`].
pub const ASSIGN_RETIRED: u64 = u64::MAX;

/// Bucketed kernel sampler state (classes stored as a plain f32 table —
/// the bucket sampler evaluates exact kernels on raw embeddings).
#[derive(Clone, Debug, PartialEq)]
pub struct BucketState {
    pub map_fingerprint: u64,
    pub tree: TreeState,
    pub classes_cols: usize,
    pub classes: Vec<f32>,
    pub bucket_size: usize,
    pub num_buckets: usize,
    pub live_ids: Vec<u32>,
    pub slot_of: Vec<u32>,
    pub bucket_live: Vec<u32>,
}

/// Uniform baseline state (live list + inverse index).
#[derive(Clone, Debug, PartialEq)]
pub struct UniformState {
    pub live: Vec<u32>,
    pub index: Vec<u32>,
}

/// Full captured state of one sampler, tagged by kind.
#[derive(Clone, Debug, PartialEq)]
pub enum SamplerState {
    Uniform(UniformState),
    Kernel(KernelState),
    Sharded(ShardedState),
    Bucket(BucketState),
}

impl SamplerState {
    /// Stable on-wire discriminant.
    pub fn kind_byte(&self) -> u8 {
        match self {
            SamplerState::Uniform(_) => 0,
            SamplerState::Kernel(_) => 1,
            SamplerState::Sharded(_) => 2,
            SamplerState::Bucket(_) => 3,
        }
    }

    /// BENCH/manifest spelling of the kind.
    pub fn kind_name(&self) -> &'static str {
        match self {
            SamplerState::Uniform(_) => "uniform",
            SamplerState::Kernel(_) => "kernel",
            SamplerState::Sharded(_) => "sharded",
            SamplerState::Bucket(_) => "bucket",
        }
    }

    /// Total slots (live + retired holes).
    pub fn num_classes(&self) -> usize {
        match self {
            SamplerState::Uniform(u) => u.index.len(),
            SamplerState::Kernel(k) => k.tree.n,
            SamplerState::Sharded(s) => s.n,
            SamplerState::Bucket(b) => b.slot_of.len(),
        }
    }

    /// Live (non-retired) classes.
    pub fn live_classes(&self) -> usize {
        match self {
            SamplerState::Uniform(u) => u.live.len(),
            SamplerState::Kernel(k) => k.tree.live,
            SamplerState::Sharded(s) => s.live,
            SamplerState::Bucket(b) => b.live_ids.len(),
        }
    }

    /// Structural validation of the whole state (delegates per kind).
    pub fn validate(&self) -> Result<(), SnapshotError> {
        match self {
            SamplerState::Uniform(u) => {
                if u.live.is_empty() {
                    return Err(SnapshotError::Malformed(
                        "uniform: no live classes",
                    ));
                }
                let n = u.index.len();
                let mut seen = vec![false; n];
                for (at, &id) in u.live.iter().enumerate() {
                    let idx = id as usize;
                    if idx >= n || seen[idx] {
                        return Err(SnapshotError::Malformed(
                            "uniform: bad live id",
                        ));
                    }
                    seen[idx] = true;
                    if u.index[idx] as usize != at {
                        return Err(SnapshotError::Malformed(
                            "uniform: inverse index disagrees",
                        ));
                    }
                }
                for (id, &at) in u.index.iter().enumerate() {
                    if at != u32::MAX && !seen[id] {
                        return Err(SnapshotError::Malformed(
                            "uniform: index marks dead slot live",
                        ));
                    }
                }
                Ok(())
            }
            SamplerState::Kernel(k) => {
                k.tree.validate()?;
                k.classes.validate()?;
                if k.classes.rows() != k.tree.n {
                    return Err(SnapshotError::Malformed(
                        "kernel: class rows != tree slots",
                    ));
                }
                Ok(())
            }
            SamplerState::Sharded(s) => {
                if s.shards.is_empty() {
                    return Err(SnapshotError::Malformed("sharded: no shards"));
                }
                for t in &s.shards {
                    t.validate()?;
                    if t.dim != s.dim {
                        return Err(SnapshotError::Malformed(
                            "sharded: shard dim disagrees",
                        ));
                    }
                }
                if s.assign.len() != s.n {
                    return Err(SnapshotError::Malformed(
                        "sharded: assign length != n",
                    ));
                }
                if s.globals.len() != s.shards.len() {
                    return Err(SnapshotError::Malformed(
                        "sharded: globals length != shard count",
                    ));
                }
                let mut live = 0usize;
                for (g, &slot) in s.assign.iter().enumerate() {
                    if slot == ASSIGN_RETIRED {
                        continue;
                    }
                    live += 1;
                    let shard = (slot >> 32) as usize;
                    let local = (slot & 0xFFFF_FFFF) as usize;
                    if shard >= s.shards.len()
                        || local >= s.globals[shard].len()
                        || s.globals[shard][local] as usize != g
                    {
                        return Err(SnapshotError::Malformed(
                            "sharded: assign/globals disagree",
                        ));
                    }
                }
                if live != s.live {
                    return Err(SnapshotError::Malformed(
                        "sharded: live count disagrees with assign",
                    ));
                }
                for (sh, t) in s.shards.iter().enumerate() {
                    if s.globals[sh].len() != t.n {
                        return Err(SnapshotError::Malformed(
                            "sharded: shard globals length != shard slots",
                        ));
                    }
                }
                s.classes.validate()?;
                if s.classes.rows() != s.n {
                    return Err(SnapshotError::Malformed(
                        "sharded: class rows != n",
                    ));
                }
                Ok(())
            }
            SamplerState::Bucket(b) => {
                b.tree.validate()?;
                if b.bucket_size == 0 {
                    return Err(SnapshotError::Malformed(
                        "bucket: bucket_size == 0",
                    ));
                }
                if b.classes_cols == 0
                    || b.classes.len() % b.classes_cols != 0
                {
                    return Err(SnapshotError::Malformed(
                        "bucket: class table shape",
                    ));
                }
                let n = b.classes.len() / b.classes_cols;
                if b.slot_of.len() != n {
                    return Err(SnapshotError::Malformed(
                        "bucket: slot_of length != n",
                    ));
                }
                if b.num_buckets != n.div_ceil(b.bucket_size)
                    || b.tree.n != b.num_buckets
                    || b.bucket_live.len() != b.num_buckets
                {
                    return Err(SnapshotError::Malformed(
                        "bucket: bucket accounting",
                    ));
                }
                if b.live_ids.len()
                    != b.bucket_live.iter().map(|&c| c as usize).sum::<usize>()
                {
                    return Err(SnapshotError::Malformed(
                        "bucket: live_ids disagree with bucket_live",
                    ));
                }
                for (at, &id) in b.live_ids.iter().enumerate() {
                    if id as usize >= n
                        || b.slot_of[id as usize] as usize != at
                    {
                        return Err(SnapshotError::Malformed(
                            "bucket: live/slot_of disagree",
                        ));
                    }
                }
                Ok(())
            }
        }
    }
}

/// One captured snapshot: sampler state plus the serving epoch at
/// capture time (the replication-log replay point for bootstrap).
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub epoch: u64,
    pub state: SamplerState,
}

// ---------------------------------------------------------------------------
// Binary codec
// ---------------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, vs: &[f32]) {
        self.usize(vs.len());
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn u32s(&mut self, vs: &[u32]) {
        self.usize(vs.len());
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn u64s(&mut self, vs: &[u64]) {
        self.usize(vs.len());
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn u16s(&mut self, vs: &[u16]) {
        self.usize(vs.len());
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
    fn i8s(&mut self, vs: &[i8]) {
        self.usize(vs.len());
        for v in vs {
            self.buf.push(*v as u8);
        }
    }
    fn bools(&mut self, vs: &[bool]) {
        self.usize(vs.len());
        for v in vs {
            self.buf.push(*v as u8);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.buf.len() - self.at < n {
            return Err(SnapshotError::Truncated);
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Length prefix, sanity-capped against remaining bytes so a
    /// corrupt length can never trigger an absurd pre-allocation.
    fn len(&mut self, elem: usize) -> Result<usize, SnapshotError> {
        let n = self.u64()? as usize;
        if n.checked_mul(elem).is_none_or(|b| b > self.buf.len() - self.at) {
            return Err(SnapshotError::Truncated);
        }
        Ok(n)
    }
    fn usize_val(&mut self) -> Result<usize, SnapshotError> {
        Ok(self.u64()? as usize)
    }
    fn f32s(&mut self) -> Result<Vec<f32>, SnapshotError> {
        let n = self.len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn u32s(&mut self) -> Result<Vec<u32>, SnapshotError> {
        let n = self.len(4)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn u64s(&mut self) -> Result<Vec<u64>, SnapshotError> {
        let n = self.len(8)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn u16s(&mut self) -> Result<Vec<u16>, SnapshotError> {
        let n = self.len(2)?;
        let raw = self.take(n * 2)?;
        Ok(raw
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn i8s(&mut self) -> Result<Vec<i8>, SnapshotError> {
        let n = self.len(1)?;
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }
    fn bools(&mut self) -> Result<Vec<bool>, SnapshotError> {
        let n = self.len(1)?;
        let raw = self.take(n)?;
        let mut out = Vec::with_capacity(n);
        for &b in raw {
            match b {
                0 => out.push(false),
                1 => out.push(true),
                _ => {
                    return Err(SnapshotError::Malformed(
                        "bool byte out of range",
                    ))
                }
            }
        }
        Ok(out)
    }
}

fn write_tree(w: &mut Writer, t: &TreeState) {
    w.usize(t.dim);
    w.usize(t.n);
    w.usize(t.pad);
    w.f32s(&t.left_sums);
    w.u32s(&t.left_live);
    w.f32s(&t.total);
    w.usize(t.live);
    w.bools(&t.retired);
    w.f64(t.eps);
    w.usize(t.growths);
}

fn read_tree(r: &mut Reader<'_>) -> Result<TreeState, SnapshotError> {
    Ok(TreeState {
        dim: r.usize_val()?,
        n: r.usize_val()?,
        pad: r.usize_val()?,
        left_sums: r.f32s()?,
        left_live: r.u32s()?,
        total: r.f32s()?,
        live: r.usize_val()?,
        retired: r.bools()?,
        eps: r.f64()?,
        growths: r.usize_val()?,
    })
}

fn write_store(w: &mut Writer, s: &ClassStoreState) {
    match s {
        ClassStoreState::F32 { cols, data } => {
            w.u8(0);
            w.usize(*cols);
            w.f32s(data);
        }
        ClassStoreState::F16 { cols, data } => {
            w.u8(1);
            w.usize(*cols);
            w.u16s(data);
        }
        ClassStoreState::I8 { cols, data, scales } => {
            w.u8(2);
            w.usize(*cols);
            w.i8s(data);
            w.f32s(scales);
        }
    }
}

fn read_store(r: &mut Reader<'_>) -> Result<ClassStoreState, SnapshotError> {
    match r.u8()? {
        0 => Ok(ClassStoreState::F32 {
            cols: r.usize_val()?,
            data: r.f32s()?,
        }),
        1 => Ok(ClassStoreState::F16 {
            cols: r.usize_val()?,
            data: r.u16s()?,
        }),
        2 => Ok(ClassStoreState::I8 {
            cols: r.usize_val()?,
            data: r.i8s()?,
            scales: r.f32s()?,
        }),
        _ => Err(SnapshotError::Malformed("unknown class-store kind")),
    }
}

/// Serialize a snapshot to its self-checking binary form.
pub fn encode(snap: &Snapshot) -> Vec<u8> {
    let mut w = Writer { buf: Vec::with_capacity(4096) };
    w.buf.extend_from_slice(&SNAPSHOT_MAGIC);
    w.u32(SNAPSHOT_VERSION);
    w.u64(snap.epoch);
    w.u8(snap.state.kind_byte());
    match &snap.state {
        SamplerState::Uniform(u) => {
            w.u32s(&u.live);
            w.u32s(&u.index);
        }
        SamplerState::Kernel(k) => {
            w.u64(k.map_fingerprint);
            write_tree(&mut w, &k.tree);
            write_store(&mut w, &k.classes);
        }
        SamplerState::Sharded(s) => {
            w.u64(s.map_fingerprint);
            w.usize(s.shards.len());
            for t in &s.shards {
                write_tree(&mut w, t);
            }
            w.u64s(&s.assign);
            w.usize(s.globals.len());
            for g in &s.globals {
                w.u32s(g);
            }
            w.usize(s.n);
            w.usize(s.live);
            w.usize(s.dim);
            w.f64(s.eps);
            w.usize(s.reserve);
            w.usize(s.target_shards);
            w.f64(s.rebalance_threshold);
            write_store(&mut w, &s.classes);
        }
        SamplerState::Bucket(b) => {
            w.u64(b.map_fingerprint);
            write_tree(&mut w, &b.tree);
            w.usize(b.classes_cols);
            w.f32s(&b.classes);
            w.usize(b.bucket_size);
            w.usize(b.num_buckets);
            w.u32s(&b.live_ids);
            w.u32s(&b.slot_of);
            w.u32s(&b.bucket_live);
        }
    }
    let sum = fnv1a(&w.buf);
    w.u64(sum);
    w.buf
}

/// Decode and structurally validate a snapshot byte stream. Rejects
/// bad magic, future versions, checksum mismatches, truncation, and
/// every malformed-payload mode with a typed error — never a panic.
pub fn decode(bytes: &[u8]) -> Result<Snapshot, SnapshotError> {
    if bytes.len() < SNAPSHOT_MAGIC.len() + 4 + 8 + 1 + 8 {
        return Err(SnapshotError::Truncated);
    }
    if bytes[..SNAPSHOT_MAGIC.len()] != SNAPSHOT_MAGIC {
        return Err(SnapshotError::BadMagic);
    }
    let body = &bytes[..bytes.len() - 8];
    let stored = u64::from_le_bytes(
        bytes[bytes.len() - 8..].try_into().unwrap(),
    );
    let computed = fnv1a(body);
    // Version is checked before the checksum so a newer-format file
    // reports FutureVersion (actionable) rather than BadChecksum.
    let mut r = Reader { buf: body, at: SNAPSHOT_MAGIC.len() };
    let version = r.u32()?;
    if version > SNAPSHOT_VERSION {
        return Err(SnapshotError::FutureVersion {
            found: version,
            max: SNAPSHOT_VERSION,
        });
    }
    if version == 0 {
        return Err(SnapshotError::Malformed("version 0"));
    }
    if stored != computed {
        return Err(SnapshotError::BadChecksum { stored, computed });
    }
    let epoch = r.u64()?;
    let kind = r.u8()?;
    let state = match kind {
        0 => SamplerState::Uniform(UniformState {
            live: r.u32s()?,
            index: r.u32s()?,
        }),
        1 => SamplerState::Kernel(KernelState {
            map_fingerprint: r.u64()?,
            tree: read_tree(&mut r)?,
            classes: read_store(&mut r)?,
        }),
        2 => {
            let map_fingerprint = r.u64()?;
            let n_shards = r.len(1)?;
            let mut shards = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                shards.push(read_tree(&mut r)?);
            }
            let assign = r.u64s()?;
            let n_globals = r.len(1)?;
            let mut globals = Vec::with_capacity(n_globals);
            for _ in 0..n_globals {
                globals.push(r.u32s()?);
            }
            SamplerState::Sharded(ShardedState {
                map_fingerprint,
                shards,
                assign,
                globals,
                n: r.usize_val()?,
                live: r.usize_val()?,
                dim: r.usize_val()?,
                eps: r.f64()?,
                reserve: r.usize_val()?,
                target_shards: r.usize_val()?,
                rebalance_threshold: r.f64()?,
                classes: read_store(&mut r)?,
            })
        }
        3 => SamplerState::Bucket(BucketState {
            map_fingerprint: r.u64()?,
            tree: read_tree(&mut r)?,
            classes_cols: r.usize_val()?,
            classes: r.f32s()?,
            bucket_size: r.usize_val()?,
            num_buckets: r.usize_val()?,
            live_ids: r.u32s()?,
            slot_of: r.u32s()?,
            bucket_live: r.u32s()?,
        }),
        _ => return Err(SnapshotError::Malformed("unknown sampler kind")),
    };
    if r.at != body.len() {
        return Err(SnapshotError::Malformed("trailing bytes"));
    }
    let snap = Snapshot { epoch, state };
    snap.state.validate()?;
    Ok(snap)
}

// ---------------------------------------------------------------------------
// File IO + manifest registration
// ---------------------------------------------------------------------------

/// Write a snapshot file atomically (tmp + rename), returning the
/// encoded byte count and checksum (the trailer value, reusable as the
/// manifest's integrity field).
pub fn write_file(
    path: &Path,
    snap: &Snapshot,
) -> Result<(usize, u64), SnapshotError> {
    let bytes = encode(snap);
    let sum = u64::from_le_bytes(
        bytes[bytes.len() - 8..].try_into().unwrap(),
    );
    let tmp = path.with_extension("rfsnap.tmp");
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok((bytes.len(), sum))
}

/// Read + decode a snapshot file.
pub fn read_file(path: &Path) -> Result<Snapshot, SnapshotError> {
    let bytes = std::fs::read(path)?;
    decode(&bytes)
}

/// Save a snapshot under `dir` as `<name>.rfsnap` and register it in
/// `dir/manifest.json` (creating or updating the manifest's
/// `snapshots` section — the [`crate::runtime::manifest`] schema).
pub fn save_with_manifest(
    dir: &Path,
    name: &str,
    snap: &Snapshot,
) -> Result<crate::runtime::manifest::SnapshotMeta, SnapshotError> {
    use crate::runtime::manifest::{Manifest, SnapshotMeta};
    std::fs::create_dir_all(dir)?;
    let file = format!("{name}.rfsnap");
    let (bytes, checksum) = write_file(&dir.join(&file), snap)?;
    let meta = SnapshotMeta {
        name: name.to_string(),
        file,
        kind: snap.state.kind_name().to_string(),
        epoch: snap.epoch,
        n_classes: snap.state.num_classes(),
        live_classes: snap.state.live_classes(),
        bytes,
        checksum,
    };
    let manifest_path = dir.join("manifest.json");
    let mut manifest = match std::fs::read_to_string(&manifest_path) {
        Ok(text) => Manifest::parse(&text)
            .map_err(|e| SnapshotError::Io(format!("manifest: {e}")))?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Manifest::default()
        }
        Err(e) => return Err(e.into()),
    };
    manifest.insert_snapshot(meta.clone());
    let tmp = manifest_path.with_extension("json.tmp");
    std::fs::write(&tmp, manifest.to_json_string())?;
    std::fs::rename(&tmp, &manifest_path)?;
    Ok(meta)
}

/// Load a named snapshot through `dir/manifest.json`, cross-checking
/// the manifest's recorded checksum against the file trailer.
pub fn load_with_manifest(
    dir: &Path,
    name: &str,
) -> Result<Snapshot, SnapshotError> {
    use crate::runtime::manifest::Manifest;
    let text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let manifest = Manifest::parse(&text)
        .map_err(|e| SnapshotError::Io(format!("manifest: {e}")))?;
    let meta = manifest.snapshot(name).ok_or_else(|| {
        SnapshotError::Io(format!("manifest has no snapshot '{name}'"))
    })?;
    let bytes = std::fs::read(dir.join(&meta.file))?;
    if bytes.len() >= 8 {
        let trailer = u64::from_le_bytes(
            bytes[bytes.len() - 8..].try_into().unwrap(),
        );
        if trailer != meta.checksum {
            return Err(SnapshotError::BadChecksum {
                stored: meta.checksum,
                computed: trailer,
            });
        }
    }
    decode(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tree(n: usize, dim: usize) -> TreeState {
        let pad = n.next_power_of_two().max(2);
        TreeState {
            dim,
            n,
            pad,
            left_sums: (0..(pad - 1) * dim).map(|i| i as f32 * 0.5).collect(),
            left_live: vec![0; pad - 1],
            total: vec![1.25; dim],
            live: n,
            retired: vec![false; n],
            eps: 1e-8,
            growths: 2,
        }
    }

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            epoch: 42,
            state: SamplerState::Kernel(KernelState {
                map_fingerprint: 0xdead_beef,
                tree: sample_tree(5, 3),
                classes: ClassStoreState::F16 {
                    cols: 2,
                    data: vec![0x3C00; 10],
                },
            }),
        }
    }

    #[test]
    fn round_trips_every_kind() {
        let kernel = sample_snapshot();
        let uniform = Snapshot {
            epoch: 7,
            state: SamplerState::Uniform(UniformState {
                live: vec![0, 2],
                index: vec![0, u32::MAX, 1],
            }),
        };
        let sharded = Snapshot {
            epoch: 9,
            state: SamplerState::Sharded(ShardedState {
                map_fingerprint: 1,
                shards: vec![sample_tree(2, 3), sample_tree(2, 3)],
                assign: vec![0, 1, 1 << 32, (1 << 32) | 1],
                globals: vec![vec![0, 1], vec![2, 3]],
                n: 4,
                live: 4,
                dim: 3,
                eps: 1e-8,
                reserve: 16,
                target_shards: 2,
                rebalance_threshold: 2.0,
                classes: ClassStoreState::I8 {
                    cols: 2,
                    data: vec![1; 8],
                    scales: vec![0.5; 4],
                },
            }),
        };
        let bucket = Snapshot {
            epoch: 3,
            state: SamplerState::Bucket(BucketState {
                map_fingerprint: 2,
                tree: sample_tree(2, 3),
                classes_cols: 2,
                classes: vec![0.1; 6],
                bucket_size: 2,
                num_buckets: 2,
                live_ids: vec![0, 1, 2],
                slot_of: vec![0, 1, 2],
                bucket_live: vec![2, 1],
            }),
        };
        for snap in [kernel, uniform, sharded, bucket] {
            let bytes = encode(&snap);
            let back = decode(&bytes).expect("decode");
            assert_eq!(back, snap);
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = encode(&sample_snapshot());
        bytes[0] ^= 0xFF;
        assert_eq!(decode(&bytes), Err(SnapshotError::BadMagic));
    }

    #[test]
    fn rejects_future_version_before_checksum() {
        let mut bytes = encode(&sample_snapshot());
        // Bump the version field; checksum is now stale too, but the
        // decoder must report the version problem (it is actionable).
        bytes[8] = 0xFF;
        match decode(&bytes) {
            Err(SnapshotError::FutureVersion { found, max }) => {
                assert!(found > max);
            }
            other => panic!("expected FutureVersion, got {other:?}"),
        }
    }

    #[test]
    fn rejects_flipped_bit_as_checksum_mismatch() {
        let mut bytes = encode(&sample_snapshot());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        assert!(matches!(
            decode(&bytes),
            Err(SnapshotError::BadChecksum { .. })
        ));
    }

    #[test]
    fn rejects_truncation_at_every_prefix_length() {
        let bytes = encode(&sample_snapshot());
        // Every strict prefix must fail *typed* — never panic. Short
        // prefixes are Truncated; longer ones may surface as a
        // checksum mismatch (the trailer moved) — both are acceptable,
        // panics and successes are not.
        for cut in 0..bytes.len() {
            let r = decode(&bytes[..cut]);
            assert!(r.is_err(), "prefix {cut} decoded successfully");
        }
    }

    #[test]
    fn rejects_absurd_length_prefix_without_allocating() {
        let snap = sample_snapshot();
        let mut bytes = encode(&snap);
        // Overwrite the first vector length (tree.left_sums, right
        // after magic+version+epoch+kind+fingerprint+dim+n+pad) with
        // u64::MAX and re-seal the checksum: must be Truncated, not an
        // OOM attempt.
        let at = 8 + 4 + 8 + 1 + 8 + 24;
        bytes[at..at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert_eq!(decode(&bytes), Err(SnapshotError::Truncated));
    }

    #[test]
    fn validate_catches_live_count_drift() {
        let mut snap = sample_snapshot();
        if let SamplerState::Kernel(k) = &mut snap.state {
            k.tree.live = 3; // n = 5, no retired flags ⇒ must be 5
        }
        let bytes = encode(&snap);
        assert!(matches!(
            decode(&bytes),
            Err(SnapshotError::Malformed(_))
        ));
    }

    #[test]
    fn probe_vector_is_deterministic_and_normalized() {
        let a = probe_vector(24);
        let b = probe_vector(24);
        assert_eq!(a, b);
        let norm: f64 =
            a.iter().map(|v| *v as f64 * *v as f64).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-6);
        // Different dims must not alias the same prefix.
        assert_ne!(probe_vector(8)[..4], probe_vector(4)[..]);
    }

    #[test]
    fn file_round_trip_with_manifest() {
        let dir = std::env::temp_dir()
            .join(format!("rfsnap-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let snap = sample_snapshot();
        let meta = save_with_manifest(&dir, "unit", &snap).expect("save");
        assert_eq!(meta.kind, "kernel");
        assert_eq!(meta.n_classes, 5);
        let back = load_with_manifest(&dir, "unit").expect("load");
        assert_eq!(back, snap);
        // Second snapshot lands beside the first in the same manifest.
        let mut other = snap.clone();
        other.epoch = 100;
        save_with_manifest(&dir, "later", &other).expect("save 2");
        let again = load_with_manifest(&dir, "unit").expect("reload");
        assert_eq!(again.epoch, 42);
        assert!(load_with_manifest(&dir, "nope").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
