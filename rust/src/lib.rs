//! # rfsoftmax — Sampled Softmax with Random Fourier Features
//!
//! A production-shaped training framework for classification problems with
//! very large output spaces (10⁴–10⁶ classes), reproducing
//! *Sampled Softmax with Random Fourier Features* (Rawat, Chen, Yu, Suresh,
//! Kumar — NeurIPS 2019).
//!
//! The headline feature is **RF-softmax**: kernel-based negative sampling
//! where classes are drawn with probability proportional to
//! `φ(c_i)ᵀ φ(h)` for a Random-Fourier-Feature map `φ`, which (for
//! L2-normalized embeddings) tightly and multiplicatively approximates the
//! softmax distribution `p_i ∝ exp(τ hᵀc_i)` while costing only
//! `O(D log n)` per sample via a divide-and-conquer tree (paper §3.1).
//!
//! ## Architecture (batch-first layers)
//!
//! * **L3 (this crate)** — the coordinator: a **batch-first sampling
//!   pipeline** (kernel trees + baselines), training event loop,
//!   parameter store + optimizers, synthetic-data substrates, metrics,
//!   CLI.
//! * **L3.5 ([`serving`])** — the online serving subsystem:
//!   [`serving::SamplerServer`] publishes epoch-versioned immutable
//!   sampler snapshots behind an O(1) atomic swap so many reader threads
//!   serve `sample`/`probability`/`top_k` while a single writer applies
//!   batched class updates to a double-buffered shadow;
//!   [`serving::MicroBatcher`] coalesces concurrent requests of *every*
//!   kind into one `map_batch` gemm + per-row tree operations fanned out
//!   on the persistent [`exec::serve_pool`] (zero per-batch thread
//!   spawns). The trainers route `update_classes` through the same
//!   machinery under `serving.double_buffer` (on by default),
//!   overlapping tree refresh with the step's loss execution.
//! * **L4 ([`transport`])** — the cross-process serving transport: a
//!   std-only, length-prefixed, versioned binary wire protocol
//!   ([`transport::wire`]) over Unix domain sockets on one machine or
//!   **TCP across machines** ([`transport::TransportServer::bind_tcp`],
//!   config `serving.listen`, `TCP_NODELAY` everywhere), with a
//!   [`transport::TransportServer`] accept loop feeding decoded
//!   requests from every connection into the shared micro-batcher (so
//!   coalescing spans connections), and a
//!   [`transport::TransportClient`] with sync and pipelined modes.
//!   Wire v3 adds **batched wave frames**: a pipelined burst packs into
//!   one frame (one header parse per wave instead of per request,
//!   `serve-bench --wave N`), the server submits the decoded wave to
//!   the batcher as ONE coalesced batch, replies to v3 peers pack the
//!   same way, and v2 single-frame peers interoperate untouched.
//!   Per-request seeds ride the wire, so identical seeds produce
//!   byte-identical draws in-process, over uds, and over tcp.
//!   Per-connection backpressure (in-flight cap + typed `ERR_OVERLOAD`
//!   sheds + reader flow control) bounds server memory against slow
//!   pipelined clients — waves are admitted or shed whole, never split
//!   across an overload boundary — and responses encode zero-copy into
//!   reused per-connection buffers.
//! * **L5 ([`cluster`])** — the replicated serving cluster: several L4
//!   servers, each holding one consistent-hash shard of the class
//!   universe, answering as one. A [`cluster::ReplicaRegistry`] owns
//!   the static replica list (`cluster.replicas`), per-replica health,
//!   the ring that maps every global class id to its owner, and the
//!   global↔local id translation; a [`cluster::ClusterRouter`] fronts
//!   the single-node client API, fanning each request out by shard
//!   ownership and merging exactly (sample via a mass-weighted
//!   two-phase split over the replicas' advertised `MASS` — the
//!   distributed analogue of the sharded tree's two-level pick — top-k
//!   via rescale-and-merge, probability via owner lookup), with
//!   deterministic per-request seeds so cluster draws are
//!   reproducible; churn enters through the router and replicates via
//!   an epoch-sequenced log with per-replica acked cursors and
//!   observable lag; failover marks dead replicas down and re-routes
//!   idempotent reads over the survivors, optionally **hedging**
//!   straggler sub-waves after a p99-derived delay. `serve-bench
//!   --replicas N` drives an N-replica in-process cluster and
//!   `bench-check --require-replica-speedup R` gates the scaling win
//!   in CI.
//!
//! ## Mutable class universe
//!
//! Every real extreme-classification deployment faces a *streaming*
//! label space: classes appear and retire under live traffic. The class
//! universe is therefore mutable end-to-end:
//!
//! * **tree** — [`sampler::KernelTree::insert_class`] appends a leaf
//!   with power-of-two capacity doubling (amortized `O(D log n)`;
//!   never a full rebuild on the hot path);
//!   [`sampler::KernelTree::retire_class`] drops the leaf from the
//!   live-count-driven ε floor, so a hole carries *exactly* zero mass;
//! * **sharded tree** — [`sampler::ShardedKernelTree`] keeps an explicit
//!   slot-assignment table: inserts route to the lightest shard, and the
//!   sampler redistributes live classes when retire-skew crosses the
//!   `sampler.rebalance` ratio;
//! * **sampler trait** — [`sampler::Sampler::add_classes`] /
//!   [`sampler::Sampler::retire_classes`] with stable ids (holes are
//!   never reused) and a typed [`sampler::VocabError`] from
//!   fixed-universe baselines. Retired classes are *masked out*: never
//!   emitted by `sample*`/`serve_queries`/`top_k` (rejection fallbacks
//!   included), and `probability` returns an exact 0;
//! * **serving** — the [`serving::SamplerWriter`] applies structural
//!   mutations to its private shadow and publishes them as ordinary
//!   epoch-versioned snapshot swaps, so readers never observe a
//!   half-grown tree; trainers expose `extend_vocab`/`retire_classes`
//!   through [`serving::DoubleBufferedSampler`];
//! * **wire** — versioned `ADD_CLASSES`/`RETIRE_CLASSES` admin frames
//!   (wire v2) drive churn cross-process through the unified
//!   [`admin::AdminSurface`] hook ([`transport::VocabAdmin`] remains as
//!   its legacy adapter), and `serve-bench --churn adds:retires`
//!   reports mutation-latency percentiles and post-churn qps.
//! ## Train-step execution ([`runtime`])
//!
//! Training executes on one of two backends behind the [`runtime`]
//! seam (config `train.backend`):
//!
//! * **native** (the default) — [`runtime::native`] runs the whole step
//!   in-process as fused f32 kernels over the [`linalg::simd`] tiers:
//!   [`runtime::native::LmStep`] / [`runtime::native::XcStep`] encode,
//!   [`runtime::native::FusedLoss`] computes the sampled loss *and*
//!   every gradient in one tile sweep over the `[target | negatives]`
//!   logits — the `−log(m·q)` correction, the accidental-hit mask, and
//!   a streaming logsumexp applied in-register, with query/class/dense
//!   gradients accumulated in the same pass and no `bsz×m` intermediate
//!   ever materialized — and [`runtime::native::FullLoss`] owns the
//!   full-softmax eval. Scratch persists across steps (the trainers'
//!   `scratch_growths` metric counts buffer growths and flatlines after
//!   warmup) and row work fans out over [`exec::serve_pool`]. Needs no
//!   artifacts, no Python, no non-default cargo features.
//! * **pjrt** (`--features pjrt` + `train.backend = pjrt`) — the legacy
//!   AOT path: JAX model fwd/bwd (`python/compile/model.py`) and Pallas
//!   RFF/loss kernels (`python/compile/kernels/`) lowered to HLO text
//!   once by `make artifacts`, executed through a PJRT CPU client. Kept
//!   as an A/B oracle; the feature is off by default so the tier-1
//!   build never needs an XLA toolchain.
//!
//! Either way, Python never runs on the training hot path:
//! [`coordinator::Trainer`] drives everything from Rust.
//!
//! ## The batch-first sampling pipeline
//!
//! Every stage of the L3 hot path operates on whole training batches
//! rather than single examples:
//!
//! 1. **[`linalg`]** supplies a blocked `Matrix::matmul_nt` gemm (both
//!    operands row-major, dispatched through the [`linalg::simd`]
//!    microkernels) and batched `axpy_rows` accumulation.
//! 2. **[`featmap`]** maps all queries at once:
//!    `FeatureMap::map_batch_into` computes `Φ = f(H · Wᵀ)` in one gemm
//!    for RFF/ORF (FWHT-scratch-amortized for SORF, constant-hoisted for
//!    the quadratic map) instead of one matvec per example.
//! 3. **[`sampler`]** exposes `Sampler::sample_batch(H, targets, m, rng)`
//!    — per-example negative draws with *exact* per-example conditioned
//!    probabilities — and `Sampler::update_classes` for batched
//!    embedding propagation. Kernel samplers fan the per-example tree
//!    walks out across the [`exec`] substrate, and the
//!    [`sampler::ShardedKernelTree`] partitions classes into
//!    power-of-two shards (alias-pick a shard by root mass, then walk
//!    within it) so disjoint-shard updates apply in parallel.
//! 4. **[`coordinator`]** requests one `SamplerService::draw_batch` per
//!    training step — shared negatives drawn round-robin from the
//!    batch's per-example queries with accidental-hit masks computed
//!    batch-wide — and pushes the step's embedding updates as one
//!    sharded batch, while the [`exec`] prefetcher keeps producing whole
//!    batches ahead of the consumer.
//!
//! ## Performance
//!
//! The raw-speed hot path is owned by three mechanisms, all on by
//! default and all observable in the BENCH JSON trajectory:
//!
//! * **Runtime-dispatched SIMD kernels** ([`linalg::simd`]) — `dot`,
//!   the register-blocked `matmul_nt` microkernel, and `axpy` resolve
//!   once at startup to AVX2+FMA (x86-64), NEON (aarch64), or the
//!   always-compiled scalar reference; every tier produces identical
//!   results for `axpy` (mul+add, no FMA contraction) and the
//!   equivalence suite pins SIMD-vs-scalar agreement on remainder
//!   lengths, ragged tiles, and NaN/inf propagation. Setting
//!   `RFSM_FORCE_SCALAR=1` pins the scalar tier for bit-for-bit
//!   reproducibility across machines (CI runs the unit suite both
//!   ways). The `simd_matmul_nt` BENCH record carries the resolved
//!   tier plus the measured speedup, and CI's
//!   `bench-check --require-simd-speedup 2` gate machine-checks the
//!   win on every push.
//! * **Cache-conscious tree walks** — each root→leaf step in
//!   [`sampler::KernelTree`] software-prefetches both children of the
//!   *next* level while the current level's dot products run, and
//!   `sample_many` eagerly fills the top memo levels once so every
//!   draw after the first walks warm cache lines.
//! * **Quantized sampler embeddings** (`sampler.quantize = none | f16
//!   | i8`) — the sampler's private class-embedding copy stores as
//!   IEEE f16 (half the memory, round-off-level drift) or as i8 with
//!   per-row scales (quarter the memory, percent-level drift);
//!   feature maps always consume the *dequantized* rows, so Σq = 1
//!   stays exact and the χ² drift suite
//!   (`integration_sampler_stats`) proves sampled distributions stay
//!   within the existing bias budget vs f32. The `quantized_sampler`
//!   BENCH cells track draws/sec + resident bytes per mode, and
//!   serving records tag both `quantize` and `simd`.
//! * **Fused native train step** ([`runtime::native`]) — the one-pass
//!   loss/grad kernels replace the composed gather → forward → loss →
//!   backward pipeline (fresh buffers per stage, full logit matrix
//!   materialized) that the artifact path executed. The
//!   `train_step_fused` BENCH record (`cargo bench --bench
//!   table2_walltime`) carries the A/B against exactly that composed
//!   baseline plus a per-stage breakdown, and CI gates the win with
//!   `bench-check --require-fused-speedup 1.5`.
//!
//! Capacity growth is amortized away too: `sampler.max_capacity`
//! pre-reserves tree slots so a known churn schedule pays zero
//! doubling copies (`growths()` exposes the counter, and
//! `bench-check --baseline` ratchets every BENCH cell against the
//! previous CI run's artifacts).
//!
//! ## Observability
//!
//! The serving path is self-describing at runtime via
//! [`metrics::live`] — a std-only, lock-free telemetry registry
//! threaded through every layer above. [`metrics::live::LiveRegistry`]
//! holds sharded atomic counters and log-bucketed latency histograms
//! (relaxed `fetch_add` on the hot path; snapshots merge shards, never
//! lock), and every served request is traced through six stages —
//! `decode → queue_wait → coalesce → gemm_wave → tree_walk →
//! encode_reply` — with batch-shared stages recording each request's
//! *share*, so per-stage counts reconcile exactly with request totals.
//! A bounded worst-N slow-request log keeps per-stage breakdowns of
//! the worst offenders. The surface is scrapeable three ways: the
//! read-only wire-v3 `STATS` admin frame (JSON over the same socket
//! serving traffic), the `rfsoftmax stats <endpoint>` CLI (whose
//! `--expect-stage-count` flag machine-checks the reconciliation
//! against a live server), and the serving BENCH records' `stages` +
//! `telemetry_overhead_pct` fields — the attributed cost of the
//! telemetry itself, budgeted at ≤ 2% and enforced by
//! `bench-check --require-telemetry-overhead 2` in CI.
//!
//! ## Durability
//!
//! The sampler's kernel-tree state is what makes near-softmax sampling
//! cheap, but it is `O(n·D)` to *build* — so it is now durable
//! ([`snapshot`]):
//!
//! * **Snapshot codec** — [`snapshot::encode`]/[`snapshot::decode`]
//!   serialize the full sampler state (tree node sums, slot/assignment
//!   tables, live set, quantized [`linalg::ClassStore`], serving
//!   epoch, capacity reservation) for every sampler kind (kernel,
//!   sharded, bucket, uniform) into a little-endian binary format:
//!   `RFSNAP` magic, a `u32` version, and an FNV-1a-64 trailer.
//!   **Versioning policy:** the version bumps only on layout changes;
//!   decoders read every version up to their own and reject newer ones
//!   with a typed `FutureVersion` error (snapshots are warm-start
//!   artifacts, not archives). Truncation, bit rot, and malformed
//!   payloads each map to their own [`snapshot::SnapshotError`] — a
//!   corrupt file can never panic a server.
//! * **Restore-into-skeleton** — [`sampler::Sampler::restore_state`]
//!   replaces a cheaply built skeleton sampler's state wholesale in
//!   `O(state)`, with the feature map verified by a φ-probe
//!   fingerprint; no φ recomputation, which is where the ≥5× warm
//!   restart win comes from (the `warm_restart` BENCH cell +
//!   `bench-check --require-restore-speedup` gate it in CI).
//! * **Serving + manifest** — snapshot/restore stage through the
//!   [`serving::SamplerWriter`] replay log as peer ops of churn, so
//!   readers never observe partial state; files register in
//!   `artifacts/manifest.json` under a `snapshots` section
//!   ([`runtime::manifest::SnapshotMeta`]).
//! * **Wire + cluster** — the wire-v3 `STATE_SNAPSHOT` admin frame
//!   streams a snapshot in chunks (the 16 MiB frame cap is respected;
//!   [`transport::TransportClient::fetch_snapshot`] reassembles), and
//!   a killed/joining replica **snapshot-bootstraps**: fetch the
//!   shard's snapshot from a live owner, restore, then replay the
//!   replication-log tail from the snapshot's epoch cursor
//!   ([`cluster::Cluster::bootstrap_replica`]) — closing the
//!   abandon-with-cursor-advance durability hole.
//! * **CLI quickstart** — `rfsoftmax snapshot <endpoint> --out dir/
//!   --name main` fetches + registers a live server's snapshot;
//!   `rfsoftmax serve-bench --restore dir/:main` boots the serve loop
//!   warm from it instead of rebuilding from embeddings.
//!
//! Admin surfaces are unified behind [`admin::AdminSurface`]: one
//! typed [`admin::AdminOp`] enum (add/retire/snapshot/restore) with a
//! single [`admin::AdminError`], implemented by the serving writer
//! handle, the coordinator's `SamplerService`, and the transport
//! client; the pre-existing per-layer methods remain as thin
//! deprecated shims for one release.
//!
//! ## Quick start
//!
//! ```no_run
//! use rfsoftmax::prelude::*;
//!
//! let mut rng = Rng::seeded(42);
//! // 1,000 classes with 32-d normalized embeddings.
//! let classes = Matrix::randn(&mut rng, 1000, 32).l2_normalized_rows();
//! // RF-softmax sampler with D = 64 random features, ν = 4.0.
//! let sampler = RffSampler::new(&classes, 64, 4.0, &mut rng);
//!
//! // Batch-first: 8 example queries, one call, 10 negatives each
//! // (example b's draw excludes targets[b], probabilities exact).
//! let queries = Matrix::randn(&mut rng, 8, 32).l2_normalized_rows();
//! let targets: Vec<u32> = (0..8).collect();
//! let batch = sampler.sample_batch(&queries, &targets, 10, &mut rng);
//! assert_eq!(batch.batch(), 8);
//! assert_eq!(batch.m(), 10);
//!
//! // Scaling further: shard the tree so batched updates parallelize.
//! let sharded = ShardedKernelSampler::with_map(
//!     &classes,
//!     RffMap::new(32, 64, 4.0, &mut rng),
//!     8,
//!     "rff-sharded",
//! );
//! let draw = sharded.sample_batch(&queries, &targets, 10, &mut rng);
//! assert_eq!(draw.total(), 80);
//!
//! // Online serving: epoch-versioned snapshots + request micro-batching
//! // (sample, probability, and top_k all coalesce into shared waves).
//! // Readers pin immutable snapshots (never blocking on the writer);
//! // the writer refreshes a shadow copy and publishes with an O(1) swap.
//! let (server, mut writer) = SamplerServer::new(sharded.fork().unwrap());
//! let batcher = std::sync::Arc::new(MicroBatcher::spawn(
//!     server.clone(),
//!     BatcherOptions::default(),
//! ));
//! let reply = batcher.sample(queries.row(0), 10, /*seed=*/ 7);
//! assert_eq!(reply.epoch, 0);
//! let (top, _epoch) = batcher.top_k(queries.row(0), 5); // best-first search
//! assert_eq!(top.len(), 5);
//! let mut emb = Matrix::zeros(1, 32);
//! emb.row_mut(0).copy_from_slice(queries.row(1));
//! writer.apply_updates(vec![3], emb); // shadow only — readers unaffected
//! assert_eq!(writer.publish(), 1);    // atomic epoch-tagged swap
//! assert_eq!(server.epoch(), 1);
//!
//! // L4 — cross-process serving: the same batcher behind a unix-socket
//! // wire protocol. Mixed queries, seeds on the wire, so draws are
//! // byte-identical to the in-process `batcher.sample` for equal seeds.
//! let sock = std::env::temp_dir()
//!     .join(format!("rfsm-quickstart-{}.sock", std::process::id()));
//! let server4 = TransportServer::bind(&sock, std::sync::Arc::clone(&batcher))
//!     .unwrap();
//! let mut client = TransportClient::connect(server4.path()).unwrap();
//! let wired = client.sample(queries.row(0), 10, /*seed=*/ 7).unwrap();
//! assert_eq!(wired.draw, batcher.sample(queries.row(0), 10, 7).draw);
//! let (_q, _epoch) = client.probability(queries.row(0), 3).unwrap();
//! let (_top, _epoch) = client.top_k(queries.row(0), 5).unwrap();
//!
//! // Dynamic vocabulary: grow and shrink the class universe at runtime
//! // (amortized O(D log n) per mutation; ids are stable, retired slots
//! // become permanent zero-probability holes). Through the serving
//! // writer this lands as one epoch-versioned snapshot swap; over the
//! // wire it travels as ADD_CLASSES/RETIRE_CLASSES admin frames
//! // (`serve-bench --transport uds --churn 3:1` drives it under load).
//! let mut growing = ShardedKernelSampler::with_map(
//!     &classes,
//!     RffMap::new(32, 64, 4.0, &mut rng),
//!     8,
//!     "rff-sharded",
//! );
//! let fresh = Matrix::randn(&mut rng, 2, 32).l2_normalized_rows();
//! let new_ids = growing.add_classes(&fresh).unwrap();
//! assert_eq!(new_ids, vec![1000, 1001]);       // appended, stable
//! growing.retire_classes(&[3]).unwrap();       // permanent hole
//! assert_eq!(growing.live_classes(), 1001);    // 1000 + 2 − 1
//! assert_eq!(growing.probability(queries.row(0), 3), 0.0);
//! ```
//!
//! See `examples/` for end-to-end training drivers and `rust/benches/` for
//! the harnesses that regenerate every table and figure of the paper
//! (plus `perf_hotpath` / `perf_serving` for the hot-path and serving
//! throughput trajectories, and `rfsoftmax serve-bench` for a closed-loop
//! load test from the CLI — `serve-bench --transport uds --mix 8:1:1`
//! drives it cross-process through the L4 wire, `--transport tcp` runs
//! the same loop over a TCP listener bound at `serving.listen`, and
//! `--wave 32` packs the pipelined bursts into wire v3 batched wave
//! frames, cutting frame-header parses per request by ~the wave size —
//! the BENCH JSON's `req_headers_per_request` field tracks it).

pub mod admin;
pub mod benchkit;
pub mod bias;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
pub mod featmap;
pub mod json;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod propkit;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod serving;
pub mod snapshot;
pub mod softmax;
pub mod tables;
pub mod transport;

/// Commonly used items, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::config::{DataConfig, ModelConfig, SamplerConfig, TrainConfig};
    pub use crate::coordinator::{Trainer, TrainerBuilder};
    pub use crate::data::{extreme::ExtremeDataset, synthlm::SynthCorpus};
    pub use crate::featmap::{
        FeatureMap, MaclaurinMap, OrfMap, QuadraticMap, RffMap, SorfMap,
    };
    pub use crate::linalg::{unit_vector, Matrix};
    pub use crate::rng::Rng;
    pub use crate::sampler::{
        AliasSampler, BatchDraw, BucketKernelSampler, ExactSoftmaxSampler,
        GumbelTopKSampler, KernelTree, LogUniformSampler, NegativeDraw,
        QuadraticSampler, RffSampler, Sampler, ServeAnswer, ServeQuery,
        ServeSampler, ShardedKernelSampler, ShardedKernelTree, UniformSampler,
        VocabError,
    };
    pub use crate::metrics::live::{LiveRegistry, Stage};
    pub use crate::serving::{
        BatcherOptions, BatcherStats, ChurnSpec, DoubleBufferedSampler,
        MicroBatcher, QueryReply, RequestMix, SamplerServer, SamplerSnapshot,
        SamplerWriter, ServeReply, TransportMode,
    };
    pub use crate::transport::{
        ClientFrameStats, Endpoint, ProtocolError, TransportClient,
        TransportServer, TransportStats, VocabAdmin,
    };
    pub use crate::cluster::{
        shard_partition, Cluster, ClusterError, ClusterOptions, ClusterQuery,
        ClusterReply, ClusterRouter, ReplicaRegistry,
    };
    pub use crate::softmax::{
        full_softmax_loss, sampled_softmax_loss, SampledLoss,
    };
    pub use crate::admin::{AdminError, AdminOp, AdminResponse, AdminSurface};
    pub use crate::snapshot::{SamplerState, Snapshot, SnapshotError};
}
