//! # rfsoftmax — Sampled Softmax with Random Fourier Features
//!
//! A production-shaped training framework for classification problems with
//! very large output spaces (10⁴–10⁶ classes), reproducing
//! *Sampled Softmax with Random Fourier Features* (Rawat, Chen, Yu, Suresh,
//! Kumar — NeurIPS 2019).
//!
//! The headline feature is **RF-softmax**: kernel-based negative sampling
//! where classes are drawn with probability proportional to
//! `φ(c_i)ᵀ φ(h)` for a Random-Fourier-Feature map `φ`, which (for
//! L2-normalized embeddings) tightly and multiplicatively approximates the
//! softmax distribution `p_i ∝ exp(τ hᵀc_i)` while costing only
//! `O(D log n)` per sample via a divide-and-conquer tree (paper §3.1).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: sampling service (kernel tree +
//!   baselines), training event loop, parameter store + optimizers,
//!   synthetic-data substrates, metrics, CLI.
//! * **L2 (JAX, build time)** — model fwd/bwd (`python/compile/model.py`),
//!   AOT-lowered to HLO text once by `make artifacts`.
//! * **L1 (Pallas, build time)** — the RFF feature-map and fused
//!   sampled-softmax-loss kernels (`python/compile/kernels/`), lowered into
//!   the same HLO.
//!
//! Python never runs on the training hot path: the [`runtime`] module loads
//! the HLO artifacts into a PJRT CPU client and [`coordinator::Trainer`]
//! drives everything from Rust.
//!
//! ## Quick start
//!
//! ```no_run
//! use rfsoftmax::prelude::*;
//!
//! let mut rng = Rng::seeded(42);
//! // 1,000 classes with 32-d normalized embeddings.
//! let classes = Matrix::randn(&mut rng, 1000, 32).l2_normalized_rows();
//! // RF-softmax sampler with D = 64 random features, ν = 4.0.
//! let mut sampler = RffSampler::new(&classes, 64, 4.0, &mut rng);
//! let h = unit_vector(&mut rng, 32);
//! let draw = sampler.sample(&h, 10, &mut rng);
//! assert_eq!(draw.ids.len(), 10);
//! ```
//!
//! See `examples/` for end-to-end training drivers and `rust/benches/` for
//! the harnesses that regenerate every table and figure of the paper.

pub mod benchkit;
pub mod bias;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
pub mod featmap;
pub mod json;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod propkit;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod softmax;
pub mod tables;

/// Commonly used items, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::config::{DataConfig, ModelConfig, SamplerConfig, TrainConfig};
    pub use crate::coordinator::{Trainer, TrainerBuilder};
    pub use crate::data::{extreme::ExtremeDataset, synthlm::SynthCorpus};
    pub use crate::featmap::{
        FeatureMap, MaclaurinMap, OrfMap, QuadraticMap, RffMap, SorfMap,
    };
    pub use crate::linalg::{unit_vector, Matrix};
    pub use crate::rng::Rng;
    pub use crate::sampler::{
        AliasSampler, BucketKernelSampler, ExactSoftmaxSampler,
        GumbelTopKSampler, KernelTree, LogUniformSampler, NegativeDraw,
        QuadraticSampler, RffSampler, Sampler, UniformSampler,
    };
    pub use crate::softmax::{
        full_softmax_loss, sampled_softmax_loss, SampledLoss,
    };
}
