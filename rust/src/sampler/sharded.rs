//! Sharded kernel sampling tree — the batch-first scaling layer over the
//! §3.1 divide-and-conquer structure.
//!
//! [`ShardedKernelTree`] partitions the `n` classes into `S` (a power of
//! two) contiguous shards, each holding its own [`KernelTree`]. Sampling
//! is two-level:
//!
//! * **across shards**: an alias table over the shards' effective root
//!   masses (`zᵀΣφ` clamped at 0 plus the ε·count floor — the same
//!   semantics a full tree applies at its root) picks a shard in `O(1)`
//!   after an `O(S·D)` mass pass shared by all `m` draws;
//! * **within a shard**: a root→leaf walk of the shard's tree,
//!   `O(D log(n/S))`.
//!
//! The returned probability is exactly `P(shard) · P(i | shard)` of the
//! procedure that produced the draw, so Σ_i q_i = 1 and the eq.-5
//! importance weights stay unbiased. The payoff is *write* parallelism:
//! embedding updates touching disjoint shards commute, so a training
//! step's batched `update_classes` fans out across shards on scoped
//! threads instead of serializing `O(D log n)` walks — and per-shard
//! trees keep update working sets small enough to stay cache-resident.
//!
//! Degenerate tail shards with a single class are safe by the
//! [`KernelTree`] `pad.max(2)` invariant (see `KernelTree::new`).
//!
//! **Mutable class universe**: the class → (shard, local-slot) map is an
//! explicit assignment table rather than arithmetic, so the universe can
//! churn at runtime: [`ShardedKernelTree::insert_class`] routes each new
//! class to the **lightest** shard (fewest live classes — amortized
//! `O(D log(n/S))` via the per-shard capacity-doubling insert) and
//! [`ShardedKernelTree::retire_class`] tombstones the slot. Retire-skew
//! can still unbalance shards; [`ShardedKernelSampler`] redistributes
//! live classes evenly when the live-count imbalance crosses the
//! `sampler.rebalance` ratio (an `O(n·D)` off-hot-path event amortized
//! over the O(n) mutations needed to create the skew).

use super::{KernelTree, NegativeDraw, Sampler, VocabError};
use crate::featmap::FeatureMap;
use crate::linalg::{ClassStore, Matrix, QuantizeKind};
use crate::rng::{AliasTable, Rng};

/// Where one global class id lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Slot {
    Live { shard: u32, local: u32 },
    /// A retired hole: the id stays valid forever, is never reused, and
    /// carries exactly zero probability.
    Retired,
}

/// Two-level (shard → leaf) kernel sampling structure.
#[derive(Clone, Debug)]
pub struct ShardedKernelTree {
    shards: Vec<KernelTree>,
    /// Global slot id → location (or tombstone). Length == `n`.
    assign: Vec<Slot>,
    /// Per-shard inverse map: local slot → global id (`u32::MAX` once
    /// the local slot is retired).
    globals: Vec<Vec<u32>>,
    /// Total slots ever created (live + retired).
    n: usize,
    /// Live classes across all shards.
    live: usize,
    dim: usize,
    eps: f64,
    /// Total class capacity to pre-reserve (`sampler.max_capacity`;
    /// 0 = none). Spread across shards so runtime inserts up to this
    /// many classes never pay a per-shard capacity-doubling copy — also
    /// re-applied by [`ShardedKernelTree::redistribute`] so a rebalance
    /// does not forfeit the reservation.
    reserve: usize,
}

impl ShardedKernelTree {
    /// Empty sharded tree for `n` classes with feature dim `dim`.
    /// `num_shards` is rounded up to a power of two and clamped to `n`.
    /// Initial assignment is contiguous blocks (the classic layout);
    /// runtime inserts then go wherever is lightest.
    pub fn new(n: usize, dim: usize, num_shards: usize, eps: f64) -> Self {
        Self::with_capacity(n, dim, num_shards, eps, 0)
    }

    /// [`ShardedKernelTree::new`] plus a total-class `capacity`
    /// pre-reservation (0 = none): each shard's tree pads for its share
    /// of `capacity` up front, so growth to that many classes performs
    /// zero doubling copies (see [`ShardedKernelTree::growths`]).
    pub fn with_capacity(
        n: usize,
        dim: usize,
        num_shards: usize,
        eps: f64,
        capacity: usize,
    ) -> Self {
        assert!(n >= 1, "ShardedKernelTree: need at least one class");
        assert!(dim >= 1);
        assert!(eps > 0.0, "ShardedKernelTree: eps must be > 0");
        assert!(num_shards >= 1, "ShardedKernelTree: need ≥ 1 shard");
        let s = num_shards.next_power_of_two().min(n.next_power_of_two());
        let shard_size = n.div_ceil(s).max(1);
        let count = n.div_ceil(shard_size);
        let per_shard = capacity.div_ceil(count);
        let shards: Vec<KernelTree> = (0..count)
            .map(|i| {
                let lo = i * shard_size;
                let hi = ((i + 1) * shard_size).min(n);
                KernelTree::with_capacity(hi - lo, dim, eps, per_shard)
            })
            .collect();
        let assign = (0..n)
            .map(|i| Slot::Live {
                shard: (i / shard_size) as u32,
                local: (i % shard_size) as u32,
            })
            .collect();
        let globals = (0..count)
            .map(|i| {
                let lo = i * shard_size;
                let hi = ((i + 1) * shard_size).min(n);
                (lo as u32..hi as u32).collect()
            })
            .collect();
        Self { shards, assign, globals, n, live: n, dim, eps, reserve: capacity }
    }

    /// Total capacity-doubling copies paid across all shard trees
    /// (0 when `with_capacity` pre-reservation covered every insert).
    pub fn growths(&self) -> usize {
        self.shards.iter().map(KernelTree::growths).sum()
    }

    pub fn num_classes(&self) -> usize {
        self.n
    }

    /// Live (non-retired) classes — the support of the distribution.
    pub fn live_classes(&self) -> usize {
        self.live
    }

    /// Whether global slot `i` has been retired.
    pub fn is_retired(&self, i: usize) -> bool {
        matches!(self.assign[i], Slot::Retired)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Per-shard live-class counts (the rebalance signal).
    pub fn shard_live_counts(&self) -> Vec<usize> {
        self.shards.iter().map(KernelTree::live_classes).collect()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Memory footprint of all shard trees' node sums, in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(KernelTree::memory_bytes).sum()
    }

    /// Same slot assignment as `other` (copyable in place).
    pub fn same_shape(&self, other: &ShardedKernelTree) -> bool {
        self.n == other.n
            && self.dim == other.dim
            && self.shards.len() == other.shards.len()
            && self.assign == other.assign
    }

    /// Copy another sharded tree's node sums into this one without
    /// reallocating — in-place state restoration for callers managing
    /// their own spare tree allocations (external double-buffer or
    /// checkpoint-restore schemes; the in-crate serving writer instead
    /// recycles whole snapshots via `Arc::try_unwrap`). Layouts must
    /// match (see [`ShardedKernelTree::same_shape`]).
    pub fn copy_state_from(&mut self, src: &ShardedKernelTree) {
        assert!(self.same_shape(src), "copy_state_from: layout mismatch");
        for (dst, s) in self.shards.iter_mut().zip(&src.shards) {
            dst.copy_state_from(s);
        }
        self.live = src.live;
        self.eps = src.eps;
    }

    /// Capture the full two-level state as plain data for the durable
    /// snapshot codec. The `assign` slot table is packed as
    /// `shard << 32 | local` with [`crate::snapshot::ASSIGN_RETIRED`]
    /// marking holes.
    pub fn to_state(
        &self,
        map_fingerprint: u64,
        target_shards: usize,
        rebalance_threshold: f64,
        classes: crate::snapshot::ClassStoreState,
    ) -> crate::snapshot::ShardedState {
        crate::snapshot::ShardedState {
            map_fingerprint,
            shards: self.shards.iter().map(KernelTree::to_state).collect(),
            assign: self
                .assign
                .iter()
                .map(|s| match s {
                    Slot::Live { shard, local } => {
                        ((*shard as u64) << 32) | *local as u64
                    }
                    Slot::Retired => crate::snapshot::ASSIGN_RETIRED,
                })
                .collect(),
            globals: self.globals.clone(),
            n: self.n,
            live: self.live,
            dim: self.dim,
            eps: self.eps,
            reserve: self.reserve,
            target_shards,
            rebalance_threshold,
            classes,
        }
    }

    /// Rebuild a sharded tree from captured state — `O(state size)`,
    /// no φ recomputation. The state is re-validated here (same typed
    /// failures as the codec's decode path) so in-process restores
    /// cannot produce a structurally inconsistent tree.
    pub fn from_state(
        s: &crate::snapshot::ShardedState,
    ) -> Result<ShardedKernelTree, crate::snapshot::SnapshotError> {
        crate::snapshot::SamplerState::Sharded(s.clone()).validate()?;
        let shards = s
            .shards
            .iter()
            .map(KernelTree::from_state)
            .collect::<Result<Vec<_>, _>>()?;
        let assign = s
            .assign
            .iter()
            .map(|&packed| {
                if packed == crate::snapshot::ASSIGN_RETIRED {
                    Slot::Retired
                } else {
                    Slot::Live {
                        shard: (packed >> 32) as u32,
                        local: (packed & 0xFFFF_FFFF) as u32,
                    }
                }
            })
            .collect();
        Ok(ShardedKernelTree {
            shards,
            assign,
            globals: s.globals.clone(),
            n: s.n,
            live: s.live,
            dim: s.dim,
            eps: s.eps,
            reserve: s.reserve,
        })
    }

    /// Location of a live class; panics on retired slots (writes to a
    /// hole are always a caller bug — reads go through `probability`,
    /// which returns an exact 0 instead).
    #[inline]
    fn loc(&self, class: usize) -> (usize, usize) {
        match self.assign[class] {
            Slot::Live { shard, local } => (shard as usize, local as usize),
            Slot::Retired => panic!("class {class} is retired"),
        }
    }

    /// Add `phi` to class `i`'s leaf (construction-time).
    pub fn add_leaf(&mut self, i: usize, phi: &[f32]) {
        self.update_leaf(i, phi);
    }

    /// Add `delta` to class `i`'s leaf and its shard's ancestor sums.
    pub fn update_leaf(&mut self, i: usize, delta: &[f32]) {
        assert!(i < self.n, "update_leaf: class {i} out of range");
        let (s, local) = self.loc(i);
        self.shards[s].update_leaf(local, delta);
    }

    /// Append a new class: routed to the **lightest** shard (fewest live
    /// classes; ties to the lowest index), amortized `O(D log(n/S))`.
    /// Returns the stable global id (`== num_classes()` before the call).
    pub fn insert_class(&mut self, phi: &[f32]) -> usize {
        let s = (0..self.shards.len())
            .min_by_key(|&s| self.shards[s].live_classes())
            .expect("ShardedKernelTree: no shards");
        let local = self.shards[s].insert_class(phi);
        debug_assert_eq!(local, self.globals[s].len());
        let g = self.n;
        self.globals[s].push(g as u32);
        self.assign.push(Slot::Live { shard: s as u32, local: local as u32 });
        self.n += 1;
        self.live += 1;
        g
    }

    /// Retire global slot `i` (subtracting its current feature vector
    /// `phi`): the slot becomes a permanent zero-mass hole. A shard may
    /// legitimately drain to zero live classes — its root weight is then
    /// forced to exactly 0 and it is never picked. `O(D log(n/S))`.
    pub fn retire_class(&mut self, i: usize, phi: &[f32]) {
        assert!(i < self.n, "retire_class: class {i} out of range");
        assert!(
            self.live > 1,
            "retire_class: cannot retire the last live class"
        );
        let (s, local) = match self.assign[i] {
            Slot::Live { shard, local } => (shard as usize, local as usize),
            Slot::Retired => panic!("retire_class: class {i} already retired"),
        };
        self.shards[s].retire_class(local, phi);
        self.globals[s][local] = u32::MAX;
        self.assign[i] = Slot::Retired;
        self.live -= 1;
    }

    /// Re-partition the **live** classes evenly across `num_shards`
    /// fresh shards (global ids preserved; retired ids stay retired).
    /// `phi_of(global, buf)` must write class `global`'s current feature
    /// vector — the tree stores only sums, so the owner of the class
    /// embeddings drives the rebuild. `O(live · D)`; called by the
    /// sampler layer when retire-skew crosses its rebalance threshold,
    /// never on the per-draw hot path.
    pub fn redistribute(
        &mut self,
        num_shards: usize,
        mut phi_of: impl FnMut(usize, &mut [f32]),
    ) {
        let live_ids: Vec<usize> = (0..self.n)
            .filter(|&i| !self.is_retired(i))
            .collect();
        let l = live_ids.len();
        assert!(l >= 1, "redistribute: no live classes");
        let s = num_shards
            .max(1)
            .next_power_of_two()
            .min(l.next_power_of_two());
        let chunk = l.div_ceil(s).max(1);
        let count = l.div_ceil(chunk);
        let mut shards = Vec::with_capacity(count);
        let mut globals: Vec<Vec<u32>> = Vec::with_capacity(count);
        let mut assign = vec![Slot::Retired; self.n];
        let mut phi = vec![0.0f32; self.dim];
        let per_shard = self.reserve.div_ceil(count);
        for sh in 0..count {
            let ids = &live_ids[sh * chunk..((sh + 1) * chunk).min(l)];
            let mut tree = KernelTree::with_capacity(
                ids.len(),
                self.dim,
                self.eps,
                per_shard,
            );
            let mut inv = Vec::with_capacity(ids.len());
            for (local, &g) in ids.iter().enumerate() {
                phi_of(g, &mut phi);
                tree.add_leaf(local, &phi);
                assign[g] =
                    Slot::Live { shard: sh as u32, local: local as u32 };
                inv.push(g as u32);
            }
            shards.push(tree);
            globals.push(inv);
        }
        self.shards = shards;
        self.globals = globals;
        self.assign = assign;
        debug_assert_eq!(self.live, l);
    }

    /// Uniform draw over live classes excluding live `target` — the
    /// never-aborting fallback for [`ShardedKernelTree::sample_negatives`]
    /// in a universe with holes. Exact `1/(live − 1)` per candidate.
    pub fn uniform_live_excluding(
        &self,
        target: usize,
        rng: &mut Rng,
    ) -> usize {
        let (ts, tl) = self.loc(target);
        let avail: Vec<usize> = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, t)| t.live_classes() - usize::from(s == ts))
            .collect();
        let total: usize = avail.iter().sum();
        assert!(total >= 1, "uniform_live_excluding: no live candidates");
        let mut u = rng.below(total as u64) as usize;
        let mut s = avail.len() - 1;
        for (i, &a) in avail.iter().enumerate() {
            if u < a {
                s = i;
                break;
            }
            u -= a;
        }
        let excl = if s == ts { Some(tl) } else { None };
        let local = self.shards[s].uniform_live_excluding(excl, rng);
        self.globals[s][local] as usize
    }

    /// Apply a batch of leaf deltas. Disjoint shards commute, so touched
    /// shards are partitioned across at most
    /// [`crate::exec::recommended_workers`] scoped threads (one thread
    /// per *group of shards*, not per shard — at 512 shards the spawn
    /// cost would otherwise dwarf the `O(D log(n/S))` walks). Within a
    /// shard, application order is the caller's slice order. Small
    /// batches stay serial.
    pub fn update_leaves_batch(&mut self, updates: &[(usize, Vec<f32>)]) {
        if updates.len() < 64 || self.shards.len() < 2 {
            for (i, delta) in updates {
                self.update_leaf(*i, delta);
            }
            return;
        }
        let mut per_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        let mut locals: Vec<u32> = Vec::with_capacity(updates.len());
        for (k, (i, _)) in updates.iter().enumerate() {
            assert!(*i < self.n, "update_leaves_batch: class {i} out of range");
            let (s, local) = self.loc(*i);
            per_shard[s].push(k);
            locals.push(local as u32);
        }
        let mut jobs: Vec<(usize, &mut KernelTree)> = self
            .shards
            .iter_mut()
            .enumerate()
            .filter(|(s, _)| !per_shard[*s].is_empty())
            .collect();
        if jobs.is_empty() {
            return;
        }
        let workers = crate::exec::recommended_workers().min(jobs.len());
        let chunk = jobs.len().div_ceil(workers);
        let per_shard = &per_shard;
        let locals = &locals;
        std::thread::scope(|scope| {
            for group in jobs.chunks_mut(chunk) {
                scope.spawn(move || {
                    for (s, tree) in group.iter_mut() {
                        for &k in &per_shard[*s] {
                            let (_, delta) = &updates[k];
                            tree.update_leaf(locals[k] as usize, delta);
                        }
                    }
                });
            }
        });
    }

    /// Effective (clamped + ε·live) root mass of every shard for query
    /// `z`, plus the total. A shard with zero live classes carries
    /// exactly zero weight (mirroring [`KernelTree`]'s dead-subtree
    /// rule), so a fully-retired shard is never picked.
    fn shard_weights(&self, z: &[f32]) -> (Vec<f64>, f64) {
        let mut weights = Vec::with_capacity(self.shards.len());
        let mut total = 0.0f64;
        for tree in &self.shards {
            let lv = tree.live_classes();
            let w = if lv == 0 {
                0.0
            } else {
                tree.mass(z).max(0.0) + self.eps * lv as f64
            };
            weights.push(w);
            total += w;
        }
        (weights, total)
    }

    /// Total effective mass across all shards for query `z` — the
    /// normalizer of [`ShardedKernelTree::probability`], advertised to
    /// cluster routers for exact cross-replica merge.
    pub fn total_mass(&self, z: &[f32]) -> f64 {
        self.shard_weights(z).1
    }

    /// Guard against an fp-boundary pick of a dead shard (weight exactly
    /// 0 should make it unreachable; alias/categorical edge rounding is
    /// the only way in): reroute to the first live shard.
    #[inline]
    fn live_shard(&self, s: usize) -> usize {
        if self.shards[s].live_classes() > 0 {
            return s;
        }
        self.shards
            .iter()
            .position(|t| t.live_classes() > 0)
            .expect("ShardedKernelTree: no live classes")
    }

    /// Draw one class: `(class, q)` with `q` the exact two-level
    /// probability. `O(S·D + D log(n/S))`.
    pub fn sample(&self, z: &[f32], rng: &mut Rng) -> (usize, f64) {
        debug_assert_eq!(z.len(), self.dim);
        let (weights, total) = self.shard_weights(z);
        let s = self.live_shard(rng.categorical(&weights));
        let (local, q_in) = self.shards[s].sample(z, rng);
        (self.globals[s][local] as usize, weights[s] / total * q_in)
    }

    /// Exact probability that sampling returns class `i` for query `z`.
    /// An exact `0.0` for retired slots.
    pub fn probability(&self, z: &[f32], i: usize) -> f64 {
        assert!(i < self.n);
        let (s, local) = match self.assign[i] {
            Slot::Live { shard, local } => (shard as usize, local as usize),
            Slot::Retired => return 0.0,
        };
        let (weights, total) = self.shard_weights(z);
        weights[s] / total * self.shards[s].probability(z, local)
    }

    /// Draw `m` classes i.i.d. for one shared query: the shard masses and
    /// their alias table are computed once (`O(S·D + S)`), then each draw
    /// is an `O(1)` shard pick plus one within-shard walk.
    pub fn sample_many(
        &self,
        z: &[f32],
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        let (weights, total) = self.shard_weights(z);
        let table = AliasTable::new(&weights);
        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        for _ in 0..m {
            let s = self.live_shard(table.sample(rng));
            let (local, q_in) = self.shards[s].sample(z, rng);
            ids.push(self.globals[s][local]);
            probs.push(weights[s] / total * q_in);
        }
        (ids, probs)
    }

    /// The `k` most probable classes for query `z`, descending. Exact:
    /// the top `k` of the union is contained in the union of per-shard
    /// top `k`s, each scaled by its shard's selection probability.
    /// `O(S · (D + k·D log(n/S)))`. `k` clamps to the live count.
    pub fn top_k(&self, z: &[f32], k: usize) -> Vec<(u32, f64)> {
        let k = k.min(self.live);
        if k == 0 {
            return Vec::new();
        }
        let (weights, total) = self.shard_weights(z);
        let mut all: Vec<(u32, f64)> = Vec::with_capacity(self.shards.len() * k);
        for (s, tree) in self.shards.iter().enumerate() {
            let frac = weights[s] / total;
            if frac <= 0.0 {
                continue;
            }
            for (local, q) in tree.top_k(z, k) {
                all.push((self.globals[s][local as usize], frac * q));
            }
        }
        all.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    /// Draw `m` negatives (`≠ target`) with probabilities renormalized by
    /// `1 − q_target`; mirrors [`KernelTree::sample_negatives`] including
    /// the never-aborting, live-aware uniform fallback.
    pub fn sample_negatives(
        &self,
        z: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        assert!(target < self.n, "sample_negatives: target out of range");
        assert!(!self.is_retired(target), "sample_negatives: retired target");
        assert!(
            self.live > 1,
            "sample_negatives: need ≥ 2 live classes to exclude one"
        );
        let q_t = self.probability(z, target);
        let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);
        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        let mut rounds = 0usize;
        while ids.len() < m
            && rounds < super::REJECTION_ROUNDS
            && q_t < super::DEGENERATE_Q
        {
            let (cand, cand_q) = self.sample_many(z, m - ids.len(), rng);
            for (id, p) in cand.iter().zip(cand_q.iter()) {
                if *id as usize != target {
                    ids.push(*id);
                    probs.push(p / renorm);
                }
            }
            rounds += 1;
        }
        while ids.len() < m {
            ids.push(self.uniform_live_excluding(target, rng) as u32);
            probs.push(1.0 / (self.live - 1) as f64);
        }
        (ids, probs)
    }
}

/// Kernel sampler over a [`ShardedKernelTree`]: the batch-first sibling
/// of the unsharded `KernelSampler` behind [`super::RffSampler`]. Holds
/// no interior mutability, so it is naturally `Send + Sync` and its
/// batch paths can fan out freely; `Clone` is what makes its serving
/// fork stream-exact.
#[derive(Clone)]
pub struct ShardedKernelSampler<M: FeatureMap> {
    map: M,
    tree: ShardedKernelTree,
    /// Copy of current class embeddings (n × d, one row per slot — rows
    /// of retired slots go stale and are never read), for recomputing
    /// φ_old and for rebalance rebuilds. Stored at the configured
    /// `sampler.quantize` precision; every φ fed to the tree comes from
    /// the *dequantized* stored row so leaf masses stay consistent with
    /// what later updates/retires recompute.
    classes: ClassStore,
    /// Shard count to rebuild toward when rebalancing.
    target_shards: usize,
    /// Live-count imbalance ratio (heaviest / lightest shard) above
    /// which a mutation triggers [`ShardedKernelTree::redistribute`].
    /// `<= 1` disables rebalancing (config key `sampler.rebalance`).
    rebalance_threshold: f64,
    name: &'static str,
}

/// Probability floor per leaf (matches the unsharded samplers).
const TREE_EPS: f64 = 1e-8;

impl<M: FeatureMap> ShardedKernelSampler<M> {
    /// Build from normalized class embeddings, partitioning into
    /// `num_shards` (rounded to a power of two).
    pub fn with_map(
        classes: &Matrix,
        map: M,
        num_shards: usize,
        name: &'static str,
    ) -> Self {
        Self::with_map_opts(
            classes,
            map,
            num_shards,
            name,
            0,
            QuantizeKind::None,
        )
    }

    /// [`ShardedKernelSampler::with_map`] plus the tree capacity
    /// pre-reservation (`sampler.max_capacity`; 0 = none) and class-copy
    /// storage precision (`sampler.quantize`).
    pub fn with_map_opts(
        classes: &Matrix,
        map: M,
        num_shards: usize,
        name: &'static str,
        capacity: usize,
        quantize: QuantizeKind,
    ) -> Self {
        let n = classes.rows();
        let d = classes.cols();
        let dim = map.output_dim();
        assert_eq!(
            d,
            map.input_dim(),
            "class embedding dim must match feature-map input dim"
        );
        let store = ClassStore::from_matrix(classes, quantize);
        let mut tree = ShardedKernelTree::with_capacity(
            n, dim, num_shards, TREE_EPS, capacity,
        );
        let mut row = vec![0.0f32; d];
        let mut phi = vec![0.0f32; dim];
        for i in 0..n {
            store.row_into(i, &mut row);
            map.map_into(&row, &mut phi);
            tree.add_leaf(i, &phi);
        }
        Self {
            map,
            tree,
            classes: store,
            target_shards: num_shards.max(1),
            rebalance_threshold: 0.0,
            name,
        }
    }

    pub fn num_shards(&self) -> usize {
        self.tree.num_shards()
    }

    /// Enable (ratio > 1) or disable live-count rebalancing. When the
    /// heaviest shard holds more than `ratio ×` the lightest shard's
    /// live classes after a mutation, the live set is re-partitioned
    /// evenly (`O(live·D)`, off the draw hot path). Config:
    /// `sampler.rebalance`.
    pub fn set_rebalance_threshold(&mut self, ratio: f64) {
        self.rebalance_threshold = ratio;
    }

    /// Shard count [`ShardedKernelTree::redistribute`] would produce for
    /// `live` classes toward `target` shards — the same arithmetic, so
    /// checking against it is idempotent (no rebuild loop).
    fn desired_shard_count(target: usize, live: usize) -> usize {
        let s = target
            .max(1)
            .next_power_of_two()
            .min(live.next_power_of_two());
        let chunk = live.div_ceil(s).max(1);
        live.div_ceil(chunk)
    }

    fn maybe_rebalance(&mut self) {
        if self.rebalance_threshold <= 1.0 {
            return;
        }
        let live = self.tree.live_classes();
        if live == 0 {
            return;
        }
        // Two triggers: retire-skew imbalance, and a shard count that
        // drifted from what the target supports (a shrinking
        // redistribute reduces the count; balanced growth alone would
        // otherwise never restore it — or the log(n/S) walk depth).
        let counts = self.tree.shard_live_counts();
        let max = counts.iter().copied().max().unwrap_or(0);
        let min = counts.iter().copied().min().unwrap_or(0);
        let skewed = self.tree.num_shards() >= 2
            && (max as f64) > self.rebalance_threshold * (min.max(1) as f64);
        // Factor-2 hysteresis: rebuilding on every ±1 drift would thrash
        // at power-of-two boundaries as live oscillates around them.
        let cur = self.tree.num_shards();
        let want = Self::desired_shard_count(self.target_shards, live);
        let count_off = want >= cur * 2 || cur >= want * 2;
        if skewed || count_off {
            let (map, classes) = (&self.map, &self.classes);
            let mut row = vec![0.0f32; classes.cols()];
            self.tree.redistribute(self.target_shards, |g, buf| {
                classes.row_into(g, &mut row);
                map.map_into(&row, buf)
            });
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.tree.memory_bytes() + self.classes.memory_bytes()
    }

    /// Storage precision of the private class copy.
    pub fn quantize(&self) -> QuantizeKind {
        self.classes.kind()
    }

    /// Capacity-doubling copies paid across all shard trees.
    pub fn growths(&self) -> usize {
        self.tree.growths()
    }

    pub fn feature_map(&self) -> &M {
        &self.map
    }
}

impl<M: FeatureMap + Clone + 'static> Sampler for ShardedKernelSampler<M> {
    fn num_classes(&self) -> usize {
        self.tree.num_classes()
    }

    fn live_classes(&self) -> usize {
        self.tree.live_classes()
    }

    /// Append new classes: φ of all rows in one `map_batch` gemm, each
    /// then routed to the lightest shard (amortized `O(D log(n/S))` per
    /// class). May trigger a rebalance afterwards.
    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        if embeddings.rows() == 0 {
            return Ok(Vec::new());
        }
        super::validate_add_dim(embeddings.cols(), self.classes.cols())?;
        // Ingest first, then φ from the *dequantized* stored rows (one
        // gemm), so leaf masses match later recomputations from the store.
        let base = self.classes.rows();
        let k = embeddings.rows();
        for r in 0..k {
            self.classes.push_row(embeddings.row(r));
        }
        let new_ids: Vec<u32> = (base..base + k).map(|i| i as u32).collect();
        let deq = self.classes.gather_rows(&new_ids);
        let phis = self.map.map_batch(&deq);
        let mut ids = Vec::with_capacity(k);
        for r in 0..k {
            let g = self.tree.insert_class(phis.row(r));
            debug_assert_eq!(g, base + r);
            ids.push(g as u32);
        }
        self.maybe_rebalance();
        Ok(ids)
    }

    /// Retire live classes (`O(D log(n/S))` each). Validated up front so
    /// a bad id poisons nothing; φ of every victim comes from one
    /// `map_batch` gemm (the batch-first idiom, matching the add path);
    /// may trigger a rebalance afterwards.
    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        super::validate_retire(
            classes,
            self.tree.num_classes(),
            self.tree.live_classes(),
            |c| self.tree.is_retired(c),
        )?;
        let (map, cls, tree) = (&self.map, &self.classes, &mut self.tree);
        super::retire_phi_batch(map, cls, classes, |c, phi| {
            tree.retire_class(c, phi)
        });
        self.maybe_rebalance();
        Ok(())
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let z = self.map.map(h);
        let (ids, probs) = self.tree.sample_many(&z, m, rng);
        NegativeDraw { ids, probs }
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        let z = self.map.map(h);
        self.tree.probability(&z, class)
    }

    /// Exact total mass: `probability(h, i) · root_mass(h)` is class
    /// `i`'s absolute (unnormalized) mass, additive across disjoint
    /// samplers — what the cluster router's mass-weighted merge needs.
    fn root_mass(&self, h: &[f32]) -> f64 {
        let z = self.map.map(h);
        self.tree.total_mass(&z)
    }

    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        // Map φ(h) once and run the walk-level primitive (the trait
        // default would re-map on every rejection round).
        let z = self.map.map(h);
        let (ids, probs) = self.tree.sample_negatives(&z, target, m, rng);
        NegativeDraw { ids, probs }
    }

    /// Batch draw: one gemm maps every query, then per-example walks fan
    /// out via [`super::fan_out_draws`] (deterministic in `rng`
    /// regardless of scheduling).
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> super::BatchDraw {
        let bsz = h.rows();
        assert_eq!(bsz, targets.len(), "sample_batch: batch mismatch");
        let queries = self.map.map_batch(h);
        let tree = &self.tree;
        let draws = super::fan_out_draws(bsz, m, rng, |b, r| {
            let (ids, probs) =
                tree.sample_negatives(queries.row(b), targets[b] as usize, m, r);
            NegativeDraw { ids, probs }
        });
        super::BatchDraw { draws }
    }

    /// Unconditioned batch draw (shared-pool contract): same gemm +
    /// fan-out, walks via [`ShardedKernelTree::sample_many`].
    fn sample_batch_shared(
        &self,
        h: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> super::BatchDraw {
        let bsz = h.rows();
        let queries = self.map.map_batch(h);
        let tree = &self.tree;
        let draws = super::fan_out_draws(bsz, m, rng, |b, r| {
            let (ids, probs) = tree.sample_many(queries.row(b), m, r);
            NegativeDraw { ids, probs }
        });
        super::BatchDraw { draws }
    }

    /// Mixed-kind serving wave: ONE gemm maps every query row regardless
    /// of kind, then per-row φ-level tree operations (sample walks,
    /// exact probability, top-k search) run via
    /// [`super::fan_out_queries`] on the persistent serve pool — sample
    /// rows on an RNG stream derived only from their own seed, so
    /// answers are independent of batch composition and thread schedule.
    fn serve_queries(
        &self,
        h: &Matrix,
        queries: &[super::ServeQuery],
    ) -> Vec<super::ServeAnswer> {
        assert_eq!(h.rows(), queries.len(), "serve_queries: length mismatch");
        let phi = self.map.map_batch(h);
        let tree = &self.tree;
        super::fan_out_queries(queries, |b| match queries[b] {
            super::ServeQuery::Sample { m, seed } => {
                let mut rng = Rng::seeded(seed);
                let (ids, probs) = tree.sample_many(phi.row(b), m, &mut rng);
                super::ServeAnswer::Sample(NegativeDraw { ids, probs })
            }
            super::ServeQuery::Probability { class } => {
                super::ServeAnswer::Probability(tree.probability(phi.row(b), class))
            }
            super::ServeQuery::TopK { k } => {
                super::ServeAnswer::TopK(tree.top_k(phi.row(b), k))
            }
        })
    }

    /// Traced serving wave: same answers as [`Self::serve_queries`]
    /// (identical gemm + per-seed walks), but attributes the batched
    /// `map_batch` gemm and the fanned-out φ-level walks to separate
    /// [`super::ServeTrace`] cells for the live-telemetry pipeline.
    fn serve_queries_traced(
        &self,
        h: &Matrix,
        queries: &[super::ServeQuery],
        trace: &mut super::ServeTrace,
    ) -> Vec<super::ServeAnswer> {
        assert_eq!(h.rows(), queries.len(), "serve_queries: length mismatch");
        let t0 = std::time::Instant::now();
        let phi = self.map.map_batch(h);
        trace.gemm_ns += t0.elapsed().as_nanos() as u64;
        let tree = &self.tree;
        let t1 = std::time::Instant::now();
        let out = super::fan_out_queries(queries, |b| match queries[b] {
            super::ServeQuery::Sample { m, seed } => {
                let mut rng = Rng::seeded(seed);
                let (ids, probs) = tree.sample_many(phi.row(b), m, &mut rng);
                super::ServeAnswer::Sample(NegativeDraw { ids, probs })
            }
            super::ServeQuery::Probability { class } => {
                super::ServeAnswer::Probability(tree.probability(phi.row(b), class))
            }
            super::ServeQuery::TopK { k } => {
                super::ServeAnswer::TopK(tree.top_k(phi.row(b), k))
            }
        });
        trace.walk_ns += t1.elapsed().as_nanos() as u64;
        out
    }

    fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        let z = self.map.map(h);
        self.tree.top_k(&z, k)
    }

    /// Serving fork: a deep copy — this sampler has no interior
    /// mutability, so the clone is `Sync` and stream-exact.
    fn fork(&self) -> Option<Box<dyn super::ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        // φ_old from the stored (dequantized) row, φ_new from the row as
        // re-read after `set_row` — the leaf delta is then exactly what
        // a later retire of this class will subtract.
        let mut row = vec![0.0f32; self.classes.cols()];
        self.classes.row_into(class, &mut row);
        let phi_old = self.map.map(&row);
        self.classes.set_row(class, embedding);
        self.classes.row_into(class, &mut row);
        let mut delta = self.map.map(&row);
        for (new, old) in delta.iter_mut().zip(phi_old.iter()) {
            *new -= old;
        }
        self.tree.update_leaf(class, &delta);
    }

    /// Batched propagation: φ_old and φ_new for every touched class come
    /// from two gemms, then the leaf deltas apply shard-parallel.
    fn update_classes(&mut self, classes: &[u32], embeddings: &Matrix) {
        let k = classes.len();
        assert_eq!(k, embeddings.rows(), "update_classes: ids/rows mismatch");
        super::debug_assert_unique(classes);
        if k == 0 {
            return;
        }
        let phi_old = self.map.map_batch(&self.classes.gather_rows(classes));
        for (r, &c) in classes.iter().enumerate() {
            self.classes.set_row(c as usize, embeddings.row(r));
        }
        // Re-read the freshly-stored rows so φ_new reflects the
        // quantized values future mutations will see as "old".
        let phi_new = self.map.map_batch(&self.classes.gather_rows(classes));
        let updates: Vec<(usize, Vec<f32>)> = (0..k)
            .map(|r| {
                let delta: Vec<f32> = phi_new
                    .row(r)
                    .iter()
                    .zip(phi_old.row(r))
                    .map(|(a, b)| a - b)
                    .collect();
                (classes[r] as usize, delta)
            })
            .collect();
        self.tree.update_leaves_batch(&updates);
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        Some(crate::snapshot::SamplerState::Sharded(self.tree.to_state(
            crate::snapshot::map_fingerprint(&self.map),
            self.target_shards,
            self.rebalance_threshold,
            crate::snapshot::ClassStoreState::capture(&self.classes),
        )))
    }

    /// Restore into this sampler as a skeleton (build it from a single
    /// dummy row with the same map + config): the fingerprint check
    /// guarantees the snapshot's tree sums are sums of *this* map's φ
    /// values, then the whole two-level tree + class store + rebalance
    /// policy are swapped in wholesale, `O(state)`.
    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        use crate::snapshot::{SamplerState, SnapshotError};
        let SamplerState::Sharded(s) = state else {
            return Err(SnapshotError::Unsupported(
                "sharded sampler cannot restore a non-sharded snapshot",
            ));
        };
        let computed = crate::snapshot::map_fingerprint(&self.map);
        if computed != s.map_fingerprint {
            return Err(SnapshotError::MapMismatch {
                stored: s.map_fingerprint,
                computed,
            });
        }
        if s.dim != self.map.output_dim() {
            return Err(SnapshotError::Malformed(
                "sharded restore: tree dim != map output dim",
            ));
        }
        if s.classes.cols() != self.map.input_dim() {
            return Err(SnapshotError::Malformed(
                "sharded restore: class cols != map input dim",
            ));
        }
        let tree = ShardedKernelTree::from_state(s)?;
        self.classes = s.classes.materialize();
        self.tree = tree;
        self.target_shards = s.target_shards.max(1);
        self.rebalance_threshold = s.rebalance_threshold;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::linalg::unit_vector;

    fn sharded_rff(
        n: usize,
        d: usize,
        shards: usize,
        seed: u64,
    ) -> (Matrix, ShardedKernelSampler<RffMap>) {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = RffMap::new(d, 64, 2.0, &mut Rng::seeded(seed + 1));
        let s = ShardedKernelSampler::with_map(&classes, map, shards, "rff-sharded");
        (classes, s)
    }

    #[test]
    fn probabilities_sum_to_one_across_shards() {
        for &(n, shards) in &[(37usize, 4usize), (64, 8), (5, 8), (100, 1)] {
            let (_, s) = sharded_rff(n, 8, shards, 200);
            let mut rng = Rng::seeded(201);
            let h = unit_vector(&mut rng, 8);
            let total: f64 = (0..n).map(|i| s.probability(&h, i)).sum();
            assert!(
                (total - 1.0).abs() < 1e-6,
                "n={n} S={shards}: Σq = {total}"
            );
        }
    }

    #[test]
    fn root_mass_is_the_exact_probability_normalizer() {
        // Per-class absolute masses q_i·M must be additive across two
        // disjoint samplers whose union is a third — the invariant the
        // cluster router's mass-weighted merge rests on.
        let (classes, whole) = sharded_rff(48, 8, 4, 300);
        let mut rng = Rng::seeded(301);
        let h = unit_vector(&mut rng, 8);
        let m_whole = whole.root_mass(&h);
        assert!(m_whole > 0.0);

        // Σ_i q_i(h)·M(h) over all classes = M(h) exactly when q sums
        // to 1 — i.e. M really is the normalizer.
        let total_q: f64 = (0..48).map(|i| whole.probability(&h, i)).sum();
        assert!((total_q - 1.0).abs() < 1e-6);

        // Split the universe in half; the halves' masses must sum to a
        // value consistent with per-class absolute masses of the whole
        // being partitioned (same ε floor per live class, raw kernel
        // mass additive over leaves).
        let rows_of = |range: std::ops::Range<usize>| {
            let data: Vec<f32> =
                range.clone().flat_map(|i| classes.row(i).to_vec()).collect();
            Matrix::from_vec(range.len(), 8, data)
        };
        let (lo, hi) = (rows_of(0..24), rows_of(24..48));
        let map = whole.feature_map().clone();
        let a = ShardedKernelSampler::with_map(&lo, map.clone(), 2, "rff-sharded");
        let b = ShardedKernelSampler::with_map(&hi, map, 2, "rff-sharded");
        let (ma, mb) = (a.root_mass(&h), b.root_mass(&h));
        // Raw kernel masses are additive over leaves and each sampler
        // clamps at ≥ 0 per shard, so the split can only gain mass at
        // clamp boundaries; with unit-normalized RFF features mass stays
        // far from the clamp and the match is tight.
        assert!(
            (ma + mb - m_whole).abs() / m_whole < 1e-3,
            "split mass {ma}+{mb} vs whole {m_whole}"
        );
        // And the merged per-class probability reproduces the whole:
        // q_union(i) = q_a(i) · ma / (ma+mb) for i in the low half.
        for i in [0usize, 7, 23] {
            let merged = a.probability(&h, i) * ma / (ma + mb);
            let want = whole.probability(&h, i);
            assert!(
                (merged - want).abs() / want.max(1e-12) < 5e-3,
                "class {i}: merged {merged} vs whole {want}"
            );
        }
    }

    #[test]
    fn sample_prob_matches_probability_query() {
        let (_, s) = sharded_rff(50, 6, 8, 210);
        let mut rng = Rng::seeded(211);
        let h = unit_vector(&mut rng, 6);
        let z = s.feature_map().map(&h);
        for _ in 0..200 {
            let (i, q) = s.tree.sample(&z, &mut rng);
            let q2 = s.tree.probability(&z, i);
            assert!(i < 50);
            assert!((q - q2).abs() < 1e-12, "q {q} vs query {q2}");
        }
    }

    #[test]
    fn single_class_tail_shards_never_walk_out_of_bounds() {
        // n = 5 with 8 requested shards ⇒ shard_size 1: every shard is the
        // degenerate single-class tree the pad.max(2) invariant protects.
        let (_, s) = sharded_rff(5, 4, 8, 220);
        assert_eq!(s.num_shards(), 5);
        let mut rng = Rng::seeded(221);
        let h = unit_vector(&mut rng, 4);
        let draw = s.sample(&h, 500, &mut rng);
        assert!(draw.ids.iter().all(|&i| (i as usize) < 5));
        let total: f64 = (0..5).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn empirical_frequency_matches_q() {
        let (_, s) = sharded_rff(24, 6, 4, 230);
        let mut rng = Rng::seeded(231);
        let h = unit_vector(&mut rng, 6);
        let trials = 100_000;
        let draw = s.sample(&h, trials, &mut rng);
        let mut counts = vec![0usize; 24];
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
        for i in 0..24 {
            let q = s.probability(&h, i);
            let freq = counts[i] as f64 / trials as f64;
            let sd = (q * (1.0 - q) / trials as f64).sqrt();
            assert!(
                (freq - q).abs() < 5.0 * sd + 1e-3,
                "class {i}: freq {freq:.5} vs q {q:.5}"
            );
        }
    }

    #[test]
    fn batched_update_matches_serial_updates() {
        // 96 distinct updated classes > the 64-update serial cutoff, so
        // this exercises the shard-parallel scoped-thread path.
        let (_, mut a) = sharded_rff(128, 6, 4, 240);
        let (_, mut b) = sharded_rff(128, 6, 4, 240);
        let mut rng = Rng::seeded(241);
        let ids: Vec<u32> = (0..96).map(|i| (i * 4 % 127) as u32).collect();
        {
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), ids.len(), "test needs distinct ids");
        }
        let mut emb = Matrix::zeros(ids.len(), 6);
        for r in 0..ids.len() {
            let e = unit_vector(&mut rng, 6);
            emb.row_mut(r).copy_from_slice(&e);
        }
        a.update_classes(&ids, &emb);
        for (r, &c) in ids.iter().enumerate() {
            b.update_class(c as usize, emb.row(r));
        }
        let h = unit_vector(&mut rng, 6);
        for i in 0..128 {
            let pa = a.probability(&h, i);
            let pb = b.probability(&h, i);
            assert!(
                (pa - pb).abs() < 1e-6 * pa.max(pb).max(1e-9),
                "class {i}: batched {pa} vs serial {pb}"
            );
        }
    }

    #[test]
    fn sample_batch_excludes_targets_with_exact_probs() {
        let (_, s) = sharded_rff(32, 8, 4, 250);
        let mut rng = Rng::seeded(251);
        let bsz = 6;
        let mut h = Matrix::zeros(bsz, 8);
        for bi in 0..bsz {
            let v = unit_vector(&mut rng, 8);
            h.row_mut(bi).copy_from_slice(&v);
        }
        let targets: Vec<u32> = (0..bsz as u32).collect();
        let batch = s.sample_batch(&h, &targets, 30, &mut rng);
        assert_eq!(batch.batch(), bsz);
        for (bi, d) in batch.draws.iter().enumerate() {
            assert_eq!(d.len(), 30);
            let t = targets[bi] as usize;
            let q_t = s.probability(h.row(bi), t);
            for (&id, &q) in d.ids.iter().zip(&d.probs) {
                assert_ne!(id as usize, t);
                let want =
                    s.probability(h.row(bi), id as usize) / (1.0 - q_t);
                assert!(
                    (q - want).abs() < 1e-9 * want.max(1e-12),
                    "example {bi} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn top_k_matches_probability_ranking_across_shards() {
        let (_, s) = sharded_rff(47, 6, 4, 270);
        let mut rng = Rng::seeded(271);
        let h = unit_vector(&mut rng, 6);
        let got = s.top_k(&h, 8);
        let mut brute: Vec<(u32, f64)> =
            (0..47).map(|i| (i as u32, s.probability(&h, i))).collect();
        brute.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        assert_eq!(got.len(), 8);
        for (j, ((gi, gq), (bi, bq))) in got.iter().zip(&brute).enumerate() {
            assert!(
                (gq - bq).abs() < 1e-12 * bq.max(1e-12),
                "rank {j}: q {gq} vs {bq}"
            );
            assert!(
                gi == bi || (gq - bq).abs() < 1e-15,
                "rank {j}: id {gi} vs {bi}"
            );
        }
    }

    #[test]
    fn serve_batch_is_seed_deterministic_across_compositions() {
        let (_, s) = sharded_rff(64, 8, 4, 280);
        let mut rng = Rng::seeded(281);
        let mut h = Matrix::zeros(5, 8);
        for b in 0..5 {
            let v = unit_vector(&mut rng, 8);
            h.row_mut(b).copy_from_slice(&v);
        }
        let seeds = [11u64, 22, 33, 44, 55];
        let full = s.serve_batch(&h, &[7; 5], &seeds);
        // Re-serve row 3 alone with its seed: identical draw.
        let mut solo = Matrix::zeros(1, 8);
        solo.row_mut(0).copy_from_slice(h.row(3));
        let alone = s.serve_batch(&solo, &[7], &[seeds[3]]);
        assert_eq!(full[3], alone[0]);
        // Claimed probabilities are the exact unconditioned q.
        for (b, d) in full.iter().enumerate() {
            for (&id, &q) in d.ids.iter().zip(&d.probs) {
                let want = s.probability(h.row(b), id as usize);
                assert!(
                    (q - want).abs() < 1e-12 * want.max(1e-12),
                    "row {b} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn serve_queries_mixed_wave_matches_single_query_paths() {
        use crate::sampler::{ServeAnswer, ServeQuery};
        let (_, s) = sharded_rff(48, 8, 4, 285);
        let mut rng = Rng::seeded(286);
        let bsz = 6;
        let mut h = Matrix::zeros(bsz, 8);
        for b in 0..bsz {
            let v = unit_vector(&mut rng, 8);
            h.row_mut(b).copy_from_slice(&v);
        }
        let queries = [
            ServeQuery::Sample { m: 40, seed: 101 },
            ServeQuery::Probability { class: 7 },
            ServeQuery::TopK { k: 5 },
            ServeQuery::Sample { m: 40, seed: 102 },
            ServeQuery::Probability { class: 31 },
            ServeQuery::TopK { k: 3 },
        ];
        let answers = s.serve_queries(&h, &queries);
        assert_eq!(answers.len(), bsz);
        for (b, (q, a)) in queries.iter().zip(&answers).enumerate() {
            match (q, a) {
                (ServeQuery::Sample { m, seed }, ServeAnswer::Sample(d)) => {
                    assert_eq!(d.len(), *m, "row {b}");
                    // Identical to a solo serve of the same (h, seed).
                    let mut solo = Matrix::zeros(1, 8);
                    solo.row_mut(0).copy_from_slice(h.row(b));
                    let alone = s.serve_batch(&solo, &[*m], &[*seed]);
                    assert_eq!(*d, alone[0], "row {b}: coalescing leaked");
                }
                (ServeQuery::Probability { class }, ServeAnswer::Probability(p)) => {
                    let want = s.probability(h.row(b), *class);
                    assert!((p - want).abs() < 1e-15, "row {b}");
                }
                (ServeQuery::TopK { k }, ServeAnswer::TopK(items)) => {
                    assert_eq!(items, &s.top_k(h.row(b), *k), "row {b}");
                }
                _ => panic!("row {b}: answer kind mismatch"),
            }
        }
    }

    #[test]
    fn fork_is_stream_exact_and_tracks_updates() {
        let (_, mut original) = sharded_rff(96, 6, 4, 290);
        let mut forked = original.fork().expect("sharded sampler must fork");
        let mut rng = Rng::seeded(291);
        let h = unit_vector(&mut rng, 6);
        // Identical draws from identical streams (deep copy, not a view).
        let a = original.sample(&h, 50, &mut Rng::seeded(77));
        let b = forked.sample(&h, 50, &mut Rng::seeded(77));
        assert_eq!(a, b);
        // Updates to one side leave the other untouched...
        let ids: Vec<u32> = (0..20).map(|i| i * 4).collect();
        let mut emb = Matrix::zeros(ids.len(), 6);
        for r in 0..ids.len() {
            let e = unit_vector(&mut rng, 6);
            emb.row_mut(r).copy_from_slice(&e);
        }
        let before = forked.probability(&h, 0);
        original.update_classes(&ids, &emb);
        assert_eq!(forked.probability(&h, 0), before);
        // ...and applying the same updates reconverges exactly.
        forked.update_classes(&ids, &emb);
        for i in 0..96 {
            let pa = original.probability(&h, i);
            let pb = forked.probability(&h, i);
            assert!(
                (pa - pb).abs() < 1e-12 * pa.max(pb).max(1e-12),
                "class {i}: {pa} vs {pb}"
            );
        }
    }

    #[test]
    fn copy_state_from_replicates_sharded_distribution() {
        let (_, a) = sharded_rff(40, 6, 4, 300);
        let (_, mut b) = sharded_rff(40, 6, 4, 301); // same layout, other state
        // Restore a's tree state into b's allocations (maps must match
        // for the *distribution* to match; copy the map explicitly as an
        // external buffer manager would).
        b.tree.copy_state_from(&a.tree);
        let mut rng = Rng::seeded(302);
        let h = unit_vector(&mut rng, 6);
        let za = a.feature_map().map(&h);
        for i in 0..40 {
            assert_eq!(a.tree.probability(&za, i), b.tree.probability(&za, i));
        }
    }

    fn sharded_quadratic(
        n: usize,
        d: usize,
        shards: usize,
        seed: u64,
    ) -> (Matrix, ShardedKernelSampler<crate::featmap::QuadraticMap>) {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = crate::featmap::QuadraticMap::new(d, 100.0, 1.0);
        let s = ShardedKernelSampler::with_map(
            &classes,
            map,
            shards,
            "quadratic-sharded",
        );
        (classes, s)
    }

    #[test]
    fn churned_universe_matches_scratch_rebuild() {
        // Adds route to the lightest shard, retires tombstone slots; the
        // final distribution must match a sampler built from scratch on
        // the surviving class set (live slots in id order). The
        // quadratic kernel is strictly positive, so the two-level
        // probability is layout-independent — churned and scratch trees
        // may shard differently yet must agree.
        let mut rng = Rng::seeded(310);
        let d = 6;
        let (classes, mut s) = sharded_quadratic(24, d, 4, 311);
        let mut all = classes.clone();
        let mut retired: Vec<bool> = vec![false; 24];
        for step in 0..6 {
            let mut add = Matrix::zeros(3, d);
            for r in 0..3 {
                let v = unit_vector(&mut rng, d);
                add.row_mut(r).copy_from_slice(&v);
            }
            let base = all.rows() as u32;
            let ids = s.add_classes(&add).unwrap();
            assert_eq!(ids, vec![base, base + 1, base + 2], "stable ids");
            for r in 0..3 {
                all.push_row(add.row(r));
                retired.push(false);
            }
            let live: Vec<u32> = (0..all.rows() as u32)
                .filter(|&i| !retired[i as usize])
                .collect();
            let victim = live[(step * 5) % live.len()];
            s.retire_classes(&[victim]).unwrap();
            retired[victim as usize] = true;
        }
        assert_eq!(s.num_classes(), 24 + 18);
        assert_eq!(s.live_classes(), 24 + 18 - 6);
        // Scratch rebuild on the live set with the same feature map.
        let live_ids: Vec<usize> =
            (0..all.rows()).filter(|&i| !retired[i]).collect();
        let mut live_mat = Matrix::zeros(0, d);
        for &g in &live_ids {
            live_mat.push_row(all.row(g));
        }
        let reference = ShardedKernelSampler::with_map(
            &live_mat,
            crate::featmap::QuadraticMap::new(d, 100.0, 1.0),
            4,
            "quadratic-sharded",
        );
        let h = unit_vector(&mut rng, d);
        for (rank, &g) in live_ids.iter().enumerate() {
            let a = s.probability(&h, g);
            let b = reference.probability(&h, rank);
            assert!(
                (a - b).abs() < 1e-3 * a.max(b).max(1e-7),
                "global {g} / rank {rank}: churned {a} vs rebuilt {b}"
            );
        }
        // Retired slots: exact zero, never drawn, absent from top-k.
        let retired_ids: Vec<u32> = (0..all.rows() as u32)
            .filter(|&i| retired[i as usize])
            .collect();
        for &r in &retired_ids {
            assert_eq!(s.probability(&h, r as usize), 0.0);
        }
        let draw = s.sample(&h, 20_000, &mut rng);
        assert!(draw.ids.iter().all(|i| !retired_ids.contains(i)));
        let top = s.top_k(&h, s.num_classes());
        assert_eq!(top.len(), s.live_classes());
        assert!(top.iter().all(|(i, _)| !retired_ids.contains(i)));
        let total: f64 =
            (0..s.num_classes()).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
    }

    #[test]
    fn rebalance_evens_live_counts_and_preserves_distribution() {
        // Quadratic kernel: strictly positive masses, so the rebuilt
        // layout must renormalize the survivors exactly (up to ε/fp).
        let mut rng = Rng::seeded(320);
        let d = 6;
        let (_, mut s) = sharded_quadratic(32, d, 4, 321);
        s.set_rebalance_threshold(2.0);
        let h = unit_vector(&mut rng, d);
        // Retire most of shard 0's block (ids 0..8 under the contiguous
        // initial layout) to force the imbalance past the threshold.
        let before: Vec<f64> =
            (0..32).map(|i| s.probability(&h, i)).collect();
        s.retire_classes(&[0, 1, 2, 3, 4, 5]).unwrap();
        let counts = s.tree.shard_live_counts();
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(
            *max as f64 <= 2.0 * (*min as f64).max(1.0),
            "rebalance did not even the shards: {counts:?}"
        );
        // Distribution over survivors: renormalized original masses.
        let surviving: f64 = (6..32).map(|i| before[i]).sum();
        for i in 6..32 {
            let want = before[i] / surviving;
            let got = s.probability(&h, i);
            assert!(
                (got - want).abs() < 1e-3 * want.max(1e-7),
                "class {i}: {got} vs renormalized {want}"
            );
        }
        // Updates and draws still work against the rebuilt layout.
        let e = unit_vector(&mut rng, d);
        s.update_class(17, &e);
        let draw = s.sample(&h, 2000, &mut rng);
        assert!(draw.ids.iter().all(|&i| i >= 6 && i < 32));
    }

    #[test]
    fn fully_retired_shard_is_never_picked() {
        // 8 classes over 4 shards of 2: retiring ids 0 and 1 drains
        // shard 0 to zero live classes.
        let mut rng = Rng::seeded(330);
        let (_, mut s) = sharded_rff(8, 4, 4, 331);
        s.retire_classes(&[0, 1]).unwrap();
        assert_eq!(s.tree.shard_live_counts()[0], 0);
        let h = unit_vector(&mut rng, 4);
        let draw = s.sample(&h, 10_000, &mut rng);
        assert!(draw.ids.iter().all(|&i| i >= 2 && i < 8));
        let total: f64 = (0..8).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
        // The live-aware uniform fallback skips the dead shard too.
        for _ in 0..2000 {
            let g = s.tree.uniform_live_excluding(5, &mut rng);
            assert!(g >= 2 && g < 8 && g != 5);
        }
    }

    #[test]
    fn memory_scales_with_shard_count() {
        // More shards ⇒ shallower trees ⇒ fewer internal node sums.
        let (_, coarse) = sharded_rff(256, 8, 1, 260);
        let (_, fine) = sharded_rff(256, 8, 16, 260);
        assert!(fine.memory_bytes() <= coarse.memory_bytes());
    }

    #[test]
    fn pre_reserved_capacity_absorbs_inserts_without_growth() {
        let mut rng = Rng::seeded(340);
        let d = 6;
        let classes = Matrix::randn(&mut rng, 8, d).l2_normalized_rows();
        let map = crate::featmap::QuadraticMap::new(d, 100.0, 1.0);
        let mut reserved = ShardedKernelSampler::with_map_opts(
            &classes,
            map.clone(),
            4,
            "quadratic-sharded",
            64,
            QuantizeKind::None,
        );
        let mut plain = ShardedKernelSampler::with_map(
            &classes,
            map,
            4,
            "quadratic-sharded",
        );
        // Grow 8 → 64 live classes; the reserved sampler must never pay
        // a shard-tree doubling copy, the plain one must pay several.
        for _ in 0..56 {
            let mut add = Matrix::zeros(1, d);
            let v = unit_vector(&mut rng, d);
            add.row_mut(0).copy_from_slice(&v);
            reserved.add_classes(&add).unwrap();
            plain.add_classes(&add).unwrap();
        }
        assert_eq!(reserved.growths(), 0, "pre-reservation must hold");
        assert!(plain.growths() > 0, "unreserved tree should have doubled");
        // Same distribution either way.
        let h = unit_vector(&mut rng, d);
        for i in 0..64 {
            let a = reserved.probability(&h, i);
            let b = plain.probability(&h, i);
            assert!(
                (a - b).abs() < 1e-9 * a.max(b).max(1e-12),
                "class {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn quantized_sharded_sampler_stays_normalized_and_close() {
        let mut rng = Rng::seeded(350);
        let d = 8;
        let classes = Matrix::randn(&mut rng, 40, d).l2_normalized_rows();
        let map = RffMap::new(d, 64, 2.0, &mut Rng::seeded(351));
        let exact = ShardedKernelSampler::with_map(
            &classes,
            map.clone(),
            4,
            "rff-sharded",
        );
        let h = unit_vector(&mut rng, d);
        for (kind, tol) in
            [(QuantizeKind::F16, 2e-3), (QuantizeKind::I8, 5e-2)]
        {
            let q = ShardedKernelSampler::with_map_opts(
                &classes,
                map.clone(),
                4,
                "rff-sharded",
                0,
                kind,
            );
            assert_eq!(q.quantize(), kind);
            assert!(q.memory_bytes() < exact.memory_bytes());
            let mut total = 0.0;
            for i in 0..40 {
                let a = exact.probability(&h, i);
                let b = q.probability(&h, i);
                assert!(
                    (a - b).abs() < tol * a.max(1e-6),
                    "{kind:?} class {i}: {a} vs {b}"
                );
                total += b;
            }
            assert!((total - 1.0).abs() < 1e-6, "{kind:?}: Σq = {total}");
        }
    }
}
