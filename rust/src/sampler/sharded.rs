//! Sharded kernel sampling tree — the batch-first scaling layer over the
//! §3.1 divide-and-conquer structure.
//!
//! [`ShardedKernelTree`] partitions the `n` classes into `S` (a power of
//! two) contiguous shards, each holding its own [`KernelTree`]. Sampling
//! is two-level:
//!
//! * **across shards**: an alias table over the shards' effective root
//!   masses (`zᵀΣφ` clamped at 0 plus the ε·count floor — the same
//!   semantics a full tree applies at its root) picks a shard in `O(1)`
//!   after an `O(S·D)` mass pass shared by all `m` draws;
//! * **within a shard**: a root→leaf walk of the shard's tree,
//!   `O(D log(n/S))`.
//!
//! The returned probability is exactly `P(shard) · P(i | shard)` of the
//! procedure that produced the draw, so Σ_i q_i = 1 and the eq.-5
//! importance weights stay unbiased. The payoff is *write* parallelism:
//! embedding updates touching disjoint shards commute, so a training
//! step's batched `update_classes` fans out across shards on scoped
//! threads instead of serializing `O(D log n)` walks — and per-shard
//! trees keep update working sets small enough to stay cache-resident.
//!
//! Degenerate tail shards with a single class are safe by the
//! [`KernelTree`] `pad.max(2)` invariant (see `KernelTree::new`).

use super::{KernelTree, NegativeDraw, Sampler};
use crate::featmap::FeatureMap;
use crate::linalg::Matrix;
use crate::rng::{AliasTable, Rng};

/// Two-level (shard → leaf) kernel sampling structure.
#[derive(Clone, Debug)]
pub struct ShardedKernelTree {
    shards: Vec<KernelTree>,
    /// Classes per shard (last shard may hold fewer).
    shard_size: usize,
    n: usize,
    dim: usize,
    eps: f64,
}

impl ShardedKernelTree {
    /// Empty sharded tree for `n` classes with feature dim `dim`.
    /// `num_shards` is rounded up to a power of two and clamped to `n`.
    pub fn new(n: usize, dim: usize, num_shards: usize, eps: f64) -> Self {
        assert!(n >= 1, "ShardedKernelTree: need at least one class");
        assert!(dim >= 1);
        assert!(eps > 0.0, "ShardedKernelTree: eps must be > 0");
        assert!(num_shards >= 1, "ShardedKernelTree: need ≥ 1 shard");
        let s = num_shards.next_power_of_two().min(n.next_power_of_two());
        let shard_size = n.div_ceil(s).max(1);
        let count = n.div_ceil(shard_size);
        let shards = (0..count)
            .map(|i| {
                let lo = i * shard_size;
                let hi = ((i + 1) * shard_size).min(n);
                KernelTree::new(hi - lo, dim, eps)
            })
            .collect();
        Self { shards, shard_size, n, dim, eps }
    }

    pub fn num_classes(&self) -> usize {
        self.n
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Memory footprint of all shard trees' node sums, in bytes.
    pub fn memory_bytes(&self) -> usize {
        self.shards.iter().map(KernelTree::memory_bytes).sum()
    }

    /// Same shard layout as `other` (copyable in place).
    pub fn same_shape(&self, other: &ShardedKernelTree) -> bool {
        self.n == other.n
            && self.dim == other.dim
            && self.shard_size == other.shard_size
            && self.shards.len() == other.shards.len()
    }

    /// Copy another sharded tree's node sums into this one without
    /// reallocating — in-place state restoration for callers managing
    /// their own spare tree allocations (external double-buffer or
    /// checkpoint-restore schemes; the in-crate serving writer instead
    /// recycles whole snapshots via `Arc::try_unwrap`). Layouts must
    /// match (see [`ShardedKernelTree::same_shape`]).
    pub fn copy_state_from(&mut self, src: &ShardedKernelTree) {
        assert!(self.same_shape(src), "copy_state_from: layout mismatch");
        for (dst, s) in self.shards.iter_mut().zip(&src.shards) {
            dst.copy_state_from(s);
        }
        self.eps = src.eps;
    }

    #[inline]
    fn shard_of(&self, class: usize) -> (usize, usize) {
        (class / self.shard_size, class % self.shard_size)
    }

    /// Add `phi` to class `i`'s leaf (construction-time).
    pub fn add_leaf(&mut self, i: usize, phi: &[f32]) {
        self.update_leaf(i, phi);
    }

    /// Add `delta` to class `i`'s leaf and its shard's ancestor sums.
    pub fn update_leaf(&mut self, i: usize, delta: &[f32]) {
        assert!(i < self.n, "update_leaf: class {i} out of range");
        let (s, local) = self.shard_of(i);
        self.shards[s].update_leaf(local, delta);
    }

    /// Apply a batch of leaf deltas. Disjoint shards commute, so touched
    /// shards are partitioned across at most
    /// [`crate::exec::recommended_workers`] scoped threads (one thread
    /// per *group of shards*, not per shard — at 512 shards the spawn
    /// cost would otherwise dwarf the `O(D log(n/S))` walks). Within a
    /// shard, application order is the caller's slice order. Small
    /// batches stay serial.
    pub fn update_leaves_batch(&mut self, updates: &[(usize, Vec<f32>)]) {
        if updates.len() < 64 || self.shards.len() < 2 {
            for (i, delta) in updates {
                self.update_leaf(*i, delta);
            }
            return;
        }
        let mut per_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (k, (i, _)) in updates.iter().enumerate() {
            assert!(*i < self.n, "update_leaves_batch: class {i} out of range");
            per_shard[i / self.shard_size].push(k);
        }
        let shard_size = self.shard_size;
        let mut jobs: Vec<(usize, &mut KernelTree)> = self
            .shards
            .iter_mut()
            .enumerate()
            .filter(|(s, _)| !per_shard[*s].is_empty())
            .collect();
        if jobs.is_empty() {
            return;
        }
        let workers = crate::exec::recommended_workers().min(jobs.len());
        let chunk = jobs.len().div_ceil(workers);
        let per_shard = &per_shard;
        std::thread::scope(|scope| {
            for group in jobs.chunks_mut(chunk) {
                scope.spawn(move || {
                    for (s, tree) in group.iter_mut() {
                        for &k in &per_shard[*s] {
                            let (i, delta) = &updates[k];
                            tree.update_leaf(*i - *s * shard_size, delta);
                        }
                    }
                });
            }
        });
    }

    /// Effective (clamped + ε·count) root mass of every shard for query
    /// `z`, plus the total. Always strictly positive per shard.
    fn shard_weights(&self, z: &[f32]) -> (Vec<f64>, f64) {
        let mut weights = Vec::with_capacity(self.shards.len());
        let mut total = 0.0f64;
        for tree in &self.shards {
            let w = tree.mass(z).max(0.0)
                + self.eps * tree.num_classes() as f64;
            weights.push(w);
            total += w;
        }
        (weights, total)
    }

    /// Draw one class: `(class, q)` with `q` the exact two-level
    /// probability. `O(S·D + D log(n/S))`.
    pub fn sample(&self, z: &[f32], rng: &mut Rng) -> (usize, f64) {
        debug_assert_eq!(z.len(), self.dim);
        let (weights, total) = self.shard_weights(z);
        let s = rng.categorical(&weights);
        let (local, q_in) = self.shards[s].sample(z, rng);
        (s * self.shard_size + local, weights[s] / total * q_in)
    }

    /// Exact probability that sampling returns class `i` for query `z`.
    pub fn probability(&self, z: &[f32], i: usize) -> f64 {
        assert!(i < self.n);
        let (weights, total) = self.shard_weights(z);
        let (s, local) = self.shard_of(i);
        weights[s] / total * self.shards[s].probability(z, local)
    }

    /// Draw `m` classes i.i.d. for one shared query: the shard masses and
    /// their alias table are computed once (`O(S·D + S)`), then each draw
    /// is an `O(1)` shard pick plus one within-shard walk.
    pub fn sample_many(
        &self,
        z: &[f32],
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        let (weights, total) = self.shard_weights(z);
        let table = AliasTable::new(&weights);
        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        for _ in 0..m {
            let s = table.sample(rng);
            let (local, q_in) = self.shards[s].sample(z, rng);
            ids.push((s * self.shard_size + local) as u32);
            probs.push(weights[s] / total * q_in);
        }
        (ids, probs)
    }

    /// The `k` most probable classes for query `z`, descending. Exact:
    /// the top `k` of the union is contained in the union of per-shard
    /// top `k`s, each scaled by its shard's selection probability.
    /// `O(S · (D + k·D log(n/S)))`.
    pub fn top_k(&self, z: &[f32], k: usize) -> Vec<(u32, f64)> {
        let k = k.min(self.n);
        if k == 0 {
            return Vec::new();
        }
        let (weights, total) = self.shard_weights(z);
        let mut all: Vec<(u32, f64)> = Vec::with_capacity(self.shards.len() * k);
        for (s, tree) in self.shards.iter().enumerate() {
            let frac = weights[s] / total;
            if frac <= 0.0 {
                continue;
            }
            for (local, q) in tree.top_k(z, k) {
                all.push((
                    (s * self.shard_size + local as usize) as u32,
                    frac * q,
                ));
            }
        }
        all.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    /// Draw `m` negatives (`≠ target`) with probabilities renormalized by
    /// `1 − q_target`; mirrors [`KernelTree::sample_negatives`] including
    /// the never-aborting uniform fallback.
    pub fn sample_negatives(
        &self,
        z: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        assert!(target < self.n, "sample_negatives: target out of range");
        assert!(
            self.n > 1,
            "sample_negatives: need ≥ 2 classes to exclude one"
        );
        let q_t = self.probability(z, target);
        let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);
        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        let mut rounds = 0usize;
        while ids.len() < m
            && rounds < super::REJECTION_ROUNDS
            && q_t < super::DEGENERATE_Q
        {
            let (cand, cand_q) = self.sample_many(z, m - ids.len(), rng);
            for (id, p) in cand.iter().zip(cand_q.iter()) {
                if *id as usize != target {
                    ids.push(*id);
                    probs.push(p / renorm);
                }
            }
            rounds += 1;
        }
        while ids.len() < m {
            ids.push(super::uniform_excluding(self.n, target, rng) as u32);
            probs.push(1.0 / (self.n - 1) as f64);
        }
        (ids, probs)
    }
}

/// Kernel sampler over a [`ShardedKernelTree`]: the batch-first sibling
/// of the unsharded `KernelSampler` behind [`super::RffSampler`]. Holds
/// no interior mutability, so it is naturally `Send + Sync` and its
/// batch paths can fan out freely; `Clone` is what makes its serving
/// fork stream-exact.
#[derive(Clone)]
pub struct ShardedKernelSampler<M: FeatureMap> {
    map: M,
    tree: ShardedKernelTree,
    /// Copy of current class embeddings (n × d), for recomputing φ_old.
    classes: Matrix,
    name: &'static str,
}

/// Probability floor per leaf (matches the unsharded samplers).
const TREE_EPS: f64 = 1e-8;

impl<M: FeatureMap> ShardedKernelSampler<M> {
    /// Build from normalized class embeddings, partitioning into
    /// `num_shards` (rounded to a power of two).
    pub fn with_map(
        classes: &Matrix,
        map: M,
        num_shards: usize,
        name: &'static str,
    ) -> Self {
        let n = classes.rows();
        let dim = map.output_dim();
        assert_eq!(
            classes.cols(),
            map.input_dim(),
            "class embedding dim must match feature-map input dim"
        );
        let mut tree = ShardedKernelTree::new(n, dim, num_shards, TREE_EPS);
        let mut phi = vec![0.0f32; dim];
        for i in 0..n {
            map.map_into(classes.row(i), &mut phi);
            tree.add_leaf(i, &phi);
        }
        Self { map, tree, classes: classes.clone(), name }
    }

    pub fn num_shards(&self) -> usize {
        self.tree.num_shards()
    }

    pub fn memory_bytes(&self) -> usize {
        self.tree.memory_bytes()
            + self.classes.data().len() * std::mem::size_of::<f32>()
    }

    pub fn feature_map(&self) -> &M {
        &self.map
    }
}

impl<M: FeatureMap + Clone + 'static> Sampler for ShardedKernelSampler<M> {
    fn num_classes(&self) -> usize {
        self.tree.num_classes()
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let z = self.map.map(h);
        let (ids, probs) = self.tree.sample_many(&z, m, rng);
        NegativeDraw { ids, probs }
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        let z = self.map.map(h);
        self.tree.probability(&z, class)
    }

    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        // Map φ(h) once and run the walk-level primitive (the trait
        // default would re-map on every rejection round).
        let z = self.map.map(h);
        let (ids, probs) = self.tree.sample_negatives(&z, target, m, rng);
        NegativeDraw { ids, probs }
    }

    /// Batch draw: one gemm maps every query, then per-example walks fan
    /// out via [`super::fan_out_draws`] (deterministic in `rng`
    /// regardless of scheduling).
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> super::BatchDraw {
        let bsz = h.rows();
        assert_eq!(bsz, targets.len(), "sample_batch: batch mismatch");
        let queries = self.map.map_batch(h);
        let tree = &self.tree;
        let draws = super::fan_out_draws(bsz, m, rng, |b, r| {
            let (ids, probs) =
                tree.sample_negatives(queries.row(b), targets[b] as usize, m, r);
            NegativeDraw { ids, probs }
        });
        super::BatchDraw { draws }
    }

    /// Unconditioned batch draw (shared-pool contract): same gemm +
    /// fan-out, walks via [`ShardedKernelTree::sample_many`].
    fn sample_batch_shared(
        &self,
        h: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> super::BatchDraw {
        let bsz = h.rows();
        let queries = self.map.map_batch(h);
        let tree = &self.tree;
        let draws = super::fan_out_draws(bsz, m, rng, |b, r| {
            let (ids, probs) = tree.sample_many(queries.row(b), m, r);
            NegativeDraw { ids, probs }
        });
        super::BatchDraw { draws }
    }

    /// Mixed-kind serving wave: ONE gemm maps every query row regardless
    /// of kind, then per-row φ-level tree operations (sample walks,
    /// exact probability, top-k search) run via
    /// [`super::fan_out_queries`] on the persistent serve pool — sample
    /// rows on an RNG stream derived only from their own seed, so
    /// answers are independent of batch composition and thread schedule.
    fn serve_queries(
        &self,
        h: &Matrix,
        queries: &[super::ServeQuery],
    ) -> Vec<super::ServeAnswer> {
        assert_eq!(h.rows(), queries.len(), "serve_queries: length mismatch");
        let phi = self.map.map_batch(h);
        let tree = &self.tree;
        super::fan_out_queries(queries, |b| match queries[b] {
            super::ServeQuery::Sample { m, seed } => {
                let mut rng = Rng::seeded(seed);
                let (ids, probs) = tree.sample_many(phi.row(b), m, &mut rng);
                super::ServeAnswer::Sample(NegativeDraw { ids, probs })
            }
            super::ServeQuery::Probability { class } => {
                super::ServeAnswer::Probability(tree.probability(phi.row(b), class))
            }
            super::ServeQuery::TopK { k } => {
                super::ServeAnswer::TopK(tree.top_k(phi.row(b), k))
            }
        })
    }

    fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        let z = self.map.map(h);
        self.tree.top_k(&z, k)
    }

    /// Serving fork: a deep copy — this sampler has no interior
    /// mutability, so the clone is `Sync` and stream-exact.
    fn fork(&self) -> Option<Box<dyn super::ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        let phi_old = self.map.map(self.classes.row(class));
        let mut delta = self.map.map(embedding);
        for (new, old) in delta.iter_mut().zip(phi_old.iter()) {
            *new -= old;
        }
        self.tree.update_leaf(class, &delta);
        self.classes.row_mut(class).copy_from_slice(embedding);
    }

    /// Batched propagation: φ_old and φ_new for every touched class come
    /// from two gemms, then the leaf deltas apply shard-parallel.
    fn update_classes(&mut self, classes: &[u32], embeddings: &Matrix) {
        let k = classes.len();
        assert_eq!(k, embeddings.rows(), "update_classes: ids/rows mismatch");
        super::debug_assert_unique(classes);
        if k == 0 {
            return;
        }
        let d = self.classes.cols();
        let mut old = Matrix::zeros(k, d);
        for (r, &c) in classes.iter().enumerate() {
            old.row_mut(r).copy_from_slice(self.classes.row(c as usize));
        }
        let phi_old = self.map.map_batch(&old);
        let phi_new = self.map.map_batch(embeddings);
        let updates: Vec<(usize, Vec<f32>)> = (0..k)
            .map(|r| {
                let delta: Vec<f32> = phi_new
                    .row(r)
                    .iter()
                    .zip(phi_old.row(r))
                    .map(|(a, b)| a - b)
                    .collect();
                (classes[r] as usize, delta)
            })
            .collect();
        self.tree.update_leaves_batch(&updates);
        for (r, &c) in classes.iter().enumerate() {
            self.classes
                .row_mut(c as usize)
                .copy_from_slice(embeddings.row(r));
        }
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::RffMap;
    use crate::linalg::unit_vector;

    fn sharded_rff(
        n: usize,
        d: usize,
        shards: usize,
        seed: u64,
    ) -> (Matrix, ShardedKernelSampler<RffMap>) {
        let mut rng = Rng::seeded(seed);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = RffMap::new(d, 64, 2.0, &mut Rng::seeded(seed + 1));
        let s = ShardedKernelSampler::with_map(&classes, map, shards, "rff-sharded");
        (classes, s)
    }

    #[test]
    fn probabilities_sum_to_one_across_shards() {
        for &(n, shards) in &[(37usize, 4usize), (64, 8), (5, 8), (100, 1)] {
            let (_, s) = sharded_rff(n, 8, shards, 200);
            let mut rng = Rng::seeded(201);
            let h = unit_vector(&mut rng, 8);
            let total: f64 = (0..n).map(|i| s.probability(&h, i)).sum();
            assert!(
                (total - 1.0).abs() < 1e-6,
                "n={n} S={shards}: Σq = {total}"
            );
        }
    }

    #[test]
    fn sample_prob_matches_probability_query() {
        let (_, s) = sharded_rff(50, 6, 8, 210);
        let mut rng = Rng::seeded(211);
        let h = unit_vector(&mut rng, 6);
        let z = s.feature_map().map(&h);
        for _ in 0..200 {
            let (i, q) = s.tree.sample(&z, &mut rng);
            let q2 = s.tree.probability(&z, i);
            assert!(i < 50);
            assert!((q - q2).abs() < 1e-12, "q {q} vs query {q2}");
        }
    }

    #[test]
    fn single_class_tail_shards_never_walk_out_of_bounds() {
        // n = 5 with 8 requested shards ⇒ shard_size 1: every shard is the
        // degenerate single-class tree the pad.max(2) invariant protects.
        let (_, s) = sharded_rff(5, 4, 8, 220);
        assert_eq!(s.num_shards(), 5);
        let mut rng = Rng::seeded(221);
        let h = unit_vector(&mut rng, 4);
        let draw = s.sample(&h, 500, &mut rng);
        assert!(draw.ids.iter().all(|&i| (i as usize) < 5));
        let total: f64 = (0..5).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }

    #[test]
    fn empirical_frequency_matches_q() {
        let (_, s) = sharded_rff(24, 6, 4, 230);
        let mut rng = Rng::seeded(231);
        let h = unit_vector(&mut rng, 6);
        let trials = 100_000;
        let draw = s.sample(&h, trials, &mut rng);
        let mut counts = vec![0usize; 24];
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
        for i in 0..24 {
            let q = s.probability(&h, i);
            let freq = counts[i] as f64 / trials as f64;
            let sd = (q * (1.0 - q) / trials as f64).sqrt();
            assert!(
                (freq - q).abs() < 5.0 * sd + 1e-3,
                "class {i}: freq {freq:.5} vs q {q:.5}"
            );
        }
    }

    #[test]
    fn batched_update_matches_serial_updates() {
        // 96 distinct updated classes > the 64-update serial cutoff, so
        // this exercises the shard-parallel scoped-thread path.
        let (_, mut a) = sharded_rff(128, 6, 4, 240);
        let (_, mut b) = sharded_rff(128, 6, 4, 240);
        let mut rng = Rng::seeded(241);
        let ids: Vec<u32> = (0..96).map(|i| (i * 4 % 127) as u32).collect();
        {
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), ids.len(), "test needs distinct ids");
        }
        let mut emb = Matrix::zeros(ids.len(), 6);
        for r in 0..ids.len() {
            let e = unit_vector(&mut rng, 6);
            emb.row_mut(r).copy_from_slice(&e);
        }
        a.update_classes(&ids, &emb);
        for (r, &c) in ids.iter().enumerate() {
            b.update_class(c as usize, emb.row(r));
        }
        let h = unit_vector(&mut rng, 6);
        for i in 0..128 {
            let pa = a.probability(&h, i);
            let pb = b.probability(&h, i);
            assert!(
                (pa - pb).abs() < 1e-6 * pa.max(pb).max(1e-9),
                "class {i}: batched {pa} vs serial {pb}"
            );
        }
    }

    #[test]
    fn sample_batch_excludes_targets_with_exact_probs() {
        let (_, s) = sharded_rff(32, 8, 4, 250);
        let mut rng = Rng::seeded(251);
        let bsz = 6;
        let mut h = Matrix::zeros(bsz, 8);
        for bi in 0..bsz {
            let v = unit_vector(&mut rng, 8);
            h.row_mut(bi).copy_from_slice(&v);
        }
        let targets: Vec<u32> = (0..bsz as u32).collect();
        let batch = s.sample_batch(&h, &targets, 30, &mut rng);
        assert_eq!(batch.batch(), bsz);
        for (bi, d) in batch.draws.iter().enumerate() {
            assert_eq!(d.len(), 30);
            let t = targets[bi] as usize;
            let q_t = s.probability(h.row(bi), t);
            for (&id, &q) in d.ids.iter().zip(&d.probs) {
                assert_ne!(id as usize, t);
                let want =
                    s.probability(h.row(bi), id as usize) / (1.0 - q_t);
                assert!(
                    (q - want).abs() < 1e-9 * want.max(1e-12),
                    "example {bi} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn top_k_matches_probability_ranking_across_shards() {
        let (_, s) = sharded_rff(47, 6, 4, 270);
        let mut rng = Rng::seeded(271);
        let h = unit_vector(&mut rng, 6);
        let got = s.top_k(&h, 8);
        let mut brute: Vec<(u32, f64)> =
            (0..47).map(|i| (i as u32, s.probability(&h, i))).collect();
        brute.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        assert_eq!(got.len(), 8);
        for (j, ((gi, gq), (bi, bq))) in got.iter().zip(&brute).enumerate() {
            assert!(
                (gq - bq).abs() < 1e-12 * bq.max(1e-12),
                "rank {j}: q {gq} vs {bq}"
            );
            assert!(
                gi == bi || (gq - bq).abs() < 1e-15,
                "rank {j}: id {gi} vs {bi}"
            );
        }
    }

    #[test]
    fn serve_batch_is_seed_deterministic_across_compositions() {
        let (_, s) = sharded_rff(64, 8, 4, 280);
        let mut rng = Rng::seeded(281);
        let mut h = Matrix::zeros(5, 8);
        for b in 0..5 {
            let v = unit_vector(&mut rng, 8);
            h.row_mut(b).copy_from_slice(&v);
        }
        let seeds = [11u64, 22, 33, 44, 55];
        let full = s.serve_batch(&h, &[7; 5], &seeds);
        // Re-serve row 3 alone with its seed: identical draw.
        let mut solo = Matrix::zeros(1, 8);
        solo.row_mut(0).copy_from_slice(h.row(3));
        let alone = s.serve_batch(&solo, &[7], &[seeds[3]]);
        assert_eq!(full[3], alone[0]);
        // Claimed probabilities are the exact unconditioned q.
        for (b, d) in full.iter().enumerate() {
            for (&id, &q) in d.ids.iter().zip(&d.probs) {
                let want = s.probability(h.row(b), id as usize);
                assert!(
                    (q - want).abs() < 1e-12 * want.max(1e-12),
                    "row {b} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn serve_queries_mixed_wave_matches_single_query_paths() {
        use crate::sampler::{ServeAnswer, ServeQuery};
        let (_, s) = sharded_rff(48, 8, 4, 285);
        let mut rng = Rng::seeded(286);
        let bsz = 6;
        let mut h = Matrix::zeros(bsz, 8);
        for b in 0..bsz {
            let v = unit_vector(&mut rng, 8);
            h.row_mut(b).copy_from_slice(&v);
        }
        let queries = [
            ServeQuery::Sample { m: 40, seed: 101 },
            ServeQuery::Probability { class: 7 },
            ServeQuery::TopK { k: 5 },
            ServeQuery::Sample { m: 40, seed: 102 },
            ServeQuery::Probability { class: 31 },
            ServeQuery::TopK { k: 3 },
        ];
        let answers = s.serve_queries(&h, &queries);
        assert_eq!(answers.len(), bsz);
        for (b, (q, a)) in queries.iter().zip(&answers).enumerate() {
            match (q, a) {
                (ServeQuery::Sample { m, seed }, ServeAnswer::Sample(d)) => {
                    assert_eq!(d.len(), *m, "row {b}");
                    // Identical to a solo serve of the same (h, seed).
                    let mut solo = Matrix::zeros(1, 8);
                    solo.row_mut(0).copy_from_slice(h.row(b));
                    let alone = s.serve_batch(&solo, &[*m], &[*seed]);
                    assert_eq!(*d, alone[0], "row {b}: coalescing leaked");
                }
                (ServeQuery::Probability { class }, ServeAnswer::Probability(p)) => {
                    let want = s.probability(h.row(b), *class);
                    assert!((p - want).abs() < 1e-15, "row {b}");
                }
                (ServeQuery::TopK { k }, ServeAnswer::TopK(items)) => {
                    assert_eq!(items, &s.top_k(h.row(b), *k), "row {b}");
                }
                _ => panic!("row {b}: answer kind mismatch"),
            }
        }
    }

    #[test]
    fn fork_is_stream_exact_and_tracks_updates() {
        let (_, mut original) = sharded_rff(96, 6, 4, 290);
        let mut forked = original.fork().expect("sharded sampler must fork");
        let mut rng = Rng::seeded(291);
        let h = unit_vector(&mut rng, 6);
        // Identical draws from identical streams (deep copy, not a view).
        let a = original.sample(&h, 50, &mut Rng::seeded(77));
        let b = forked.sample(&h, 50, &mut Rng::seeded(77));
        assert_eq!(a, b);
        // Updates to one side leave the other untouched...
        let ids: Vec<u32> = (0..20).map(|i| i * 4).collect();
        let mut emb = Matrix::zeros(ids.len(), 6);
        for r in 0..ids.len() {
            let e = unit_vector(&mut rng, 6);
            emb.row_mut(r).copy_from_slice(&e);
        }
        let before = forked.probability(&h, 0);
        original.update_classes(&ids, &emb);
        assert_eq!(forked.probability(&h, 0), before);
        // ...and applying the same updates reconverges exactly.
        forked.update_classes(&ids, &emb);
        for i in 0..96 {
            let pa = original.probability(&h, i);
            let pb = forked.probability(&h, i);
            assert!(
                (pa - pb).abs() < 1e-12 * pa.max(pb).max(1e-12),
                "class {i}: {pa} vs {pb}"
            );
        }
    }

    #[test]
    fn copy_state_from_replicates_sharded_distribution() {
        let (_, a) = sharded_rff(40, 6, 4, 300);
        let (_, mut b) = sharded_rff(40, 6, 4, 301); // same layout, other state
        // Restore a's tree state into b's allocations (maps must match
        // for the *distribution* to match; copy the map explicitly as an
        // external buffer manager would).
        b.tree.copy_state_from(&a.tree);
        let mut rng = Rng::seeded(302);
        let h = unit_vector(&mut rng, 6);
        let za = a.feature_map().map(&h);
        for i in 0..40 {
            assert_eq!(a.tree.probability(&za, i), b.tree.probability(&za, i));
        }
    }

    #[test]
    fn memory_scales_with_shard_count() {
        // More shards ⇒ shallower trees ⇒ fewer internal node sums.
        let (_, coarse) = sharded_rff(256, 8, 1, 260);
        let (_, fine) = sharded_rff(256, 8, 16, 260);
        assert!(fine.memory_bytes() <= coarse.memory_bytes());
    }
}
