//! Negative-sampling distributions for sampled softmax (paper §1.1, §3).
//!
//! A [`Sampler`] produces `m` class indices with their exact sampling
//! probabilities `q_i` — the probabilities feed the logit adjustment
//! `o′ = o − log(m·q)` (paper eq. 5) that makes the sampled partition
//! function unbiased.
//!
//! The paper's taxonomy, reproduced here:
//!
//! | Sampler | q_i | cost/sample | paper role |
//! |---|---|---|---|
//! | [`RffSampler`] | `∝ φ_RFF(c_i)ᵀφ_RFF(h)` | `O(D log n)` | **RF-softmax (the contribution)** |
//! | [`QuadraticSampler`] | `∝ α(hᵀc_i)²+β` | `O(d² log n)` | Quadratic-softmax baseline [12] |
//! | [`ExactSoftmaxSampler`] | `∝ e^{τhᵀc_i}` | `O(dn)` | EXP baseline |
//! | [`UniformSampler`] | `1/n` | `O(1)` | UNIFORM baseline |
//! | [`LogUniformSampler`] | `∝ log((i+2)/(i+1))` | `O(1)` | classic LM prior |
//! | [`AliasSampler`] | arbitrary static pmf | `O(1)` | unigram prior |
//! | [`GumbelTopKSampler`] | top-k of perturbed logits | `O(dn)` | Gumbel-trick extension [13] |
//!
//! Kernel-based samplers run on the [`KernelTree`] divide-and-conquer
//! structure of §3.1 and support `O(D log n)` embedding updates.

mod bucket;
mod kernel_samplers;
mod sharded;
mod simple;
mod tree;

pub use bucket::BucketKernelSampler;
pub use kernel_samplers::{QuadraticSampler, RffSampler};
pub use sharded::{ShardedKernelSampler, ShardedKernelTree};
pub use simple::{
    AliasSampler, ExactSoftmaxSampler, GumbelTopKSampler, LogUniformSampler,
    UniformSampler,
};
pub use tree::KernelTree;

use crate::linalg::Matrix;
use crate::rng::Rng;
use std::fmt;

/// A class-universe mutation was requested of a sampler that cannot
/// honor it (fixed-universe baselines, or malformed arguments such as
/// retiring an already-retired slot). Typed so the serving and wire
/// layers can answer with a per-request error instead of panicking a
/// shared thread.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VocabError(pub String);

impl VocabError {
    pub(crate) fn fixed(name: &str) -> Self {
        VocabError(format!(
            "sampler '{name}' has a fixed class universe (no \
             add_classes/retire_classes)"
        ))
    }
}

impl fmt::Display for VocabError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "vocab mutation failed: {}", self.0)
    }
}

impl std::error::Error for VocabError {}

/// Cap on rejection rounds before [`Sampler::sample_negatives`] (and the
/// kernel-tree equivalents) switch to the deterministic
/// uniform-excluding-target fallback. Each round attempts all still-missing
/// slots, so with any non-degenerate `q_target` the fallback is
/// unreachable in practice; it exists so production runs never abort when
/// `q_target ≈ 1`.
pub(crate) const REJECTION_ROUNDS: usize = 64;

/// `q_target` above this is treated as degenerate: rejection would loop
/// (nearly) forever, so the fallback engages immediately.
pub(crate) const DEGENERATE_Q: f64 = 1.0 - 1e-9;

/// Map a uniform draw over `n − 1` slots onto class ids skipping `target`.
#[inline]
pub(crate) fn uniform_excluding(
    n: usize,
    target: usize,
    rng: &mut Rng,
) -> usize {
    debug_assert!(n > 1);
    let k = rng.index(n - 1);
    if k >= target {
        k + 1
    } else {
        k
    }
}

/// Shared fan-out for batched per-example draws: pre-splits one RNG
/// stream per example (so results are deterministic in `rng` regardless
/// of thread scheduling) and spreads the walks across the exec substrate
/// when the batch is large enough to amortize the spawn cost.
pub(crate) fn fan_out_draws(
    bsz: usize,
    m: usize,
    rng: &mut Rng,
    draw: impl Fn(usize, &mut Rng) -> NegativeDraw + Sync,
) -> Vec<NegativeDraw> {
    let streams: Vec<Rng> = (0..bsz).map(|_| rng.split()).collect();
    let run = |b: usize| {
        let mut r = streams[b].clone();
        draw(b, &mut r)
    };
    let workers = crate::exec::recommended_workers().min(bsz.max(1));
    if workers > 1 && bsz > 1 && bsz * m >= 64 {
        crate::exec::parallel_map(bsz, workers, run)
    } else {
        (0..bsz).map(run).collect()
    }
}

/// Shared fan-out for the serving path ([`Sampler::serve_queries`]
/// overrides): row `b`'s answer is computed by `answer(b)` — for sample
/// queries on an RNG stream derived only from the request's own seed, so
/// results depend on nothing but (query, sampler state), not batch
/// composition or thread schedule.
///
/// Rows run on the persistent [`crate::exec::serve_pool`] via
/// [`crate::exec::serve_map`] — zero per-batch thread spawns on the
/// serve path (ROADMAP item; the old scoped-spawn route needed a 256-walk
/// cutoff just to amortize `clone(2)`). The remaining cutoff only guards
/// FIFO-dispatch overhead for tiny waves, so it matches
/// [`fan_out_draws`]'s 64-walk threshold.
pub(crate) fn fan_out_queries(
    queries: &[ServeQuery],
    answer: impl Fn(usize) -> ServeAnswer + Sync,
) -> Vec<ServeAnswer> {
    let bsz = queries.len();
    if bsz == 0 {
        return Vec::new();
    }
    // Rough walk-count cost per query kind: a sample is m walks, a top-k
    // is a best-first search over ~k frontier expansions (heavier per
    // unit, hence the factor), a probability is one root→leaf product.
    let total: usize = queries
        .iter()
        .map(|q| match q {
            ServeQuery::Sample { m, .. } => *m,
            ServeQuery::TopK { k } => *k * 4,
            ServeQuery::Probability { .. } => 1,
        })
        .sum();
    let workers = crate::exec::recommended_workers().min(bsz);
    if workers > 1 && bsz > 1 && total >= 64 {
        crate::exec::serve_map(bsz, workers, answer)
    } else {
        (0..bsz).map(answer).collect()
    }
}

/// Shared up-front validation for [`Sampler::retire_classes`]
/// implementations: every id must be in range, live, and unique, and at
/// least one live class must survive. Errors before any mutation, so a
/// bad batch leaves the sampler untouched.
pub(crate) fn validate_retire(
    classes: &[u32],
    n: usize,
    live: usize,
    is_retired: impl Fn(usize) -> bool,
) -> Result<(), VocabError> {
    let mut seen = std::collections::HashSet::with_capacity(classes.len());
    for &c in classes {
        if c as usize >= n {
            return Err(VocabError(format!(
                "retire_classes: class {c} out of range (n = {n})"
            )));
        }
        if is_retired(c as usize) {
            return Err(VocabError(format!(
                "retire_classes: class {c} already retired"
            )));
        }
        if !seen.insert(c) {
            return Err(VocabError(format!(
                "retire_classes: duplicate class {c}"
            )));
        }
    }
    if live <= classes.len() {
        return Err(VocabError(format!(
            "retire_classes: would retire all {live} live classes"
        )));
    }
    Ok(())
}

/// Batched φ recomputation for the kernel samplers' retire paths:
/// gather the victims' (dequantized) embedding rows, ONE `map_batch`
/// gemm, then apply `retire(class, φ)` per victim — the batch-first
/// sibling of the add path, shared so the gather/map/apply sequence
/// exists once. Reading through [`crate::linalg::ClassStore`] keeps the
/// subtracted φ identical to what the quantized ingest originally added.
pub(crate) fn retire_phi_batch<M: crate::featmap::FeatureMap>(
    map: &M,
    classes: &crate::linalg::ClassStore,
    ids: &[u32],
    mut retire: impl FnMut(usize, &[f32]),
) {
    let victims = classes.gather_rows(ids);
    let phis = map.map_batch(&victims);
    for (r, &c) in ids.iter().enumerate() {
        retire(c as usize, phis.row(r));
    }
}

/// Shared embedding-width check for [`Sampler::add_classes`]
/// implementations.
pub(crate) fn validate_add_dim(got: usize, want: usize) -> Result<(), VocabError> {
    if got == want {
        Ok(())
    } else {
        Err(VocabError(format!(
            "add_classes: embedding dim {got} != class dim {want}"
        )))
    }
}

/// Debug-build check that a batched-update id list is duplicate-free
/// (duplicates would make φ_old-based delta computation corrupt tree
/// sums; the serial trait default is the only duplicate-safe path).
#[inline]
pub(crate) fn debug_assert_unique(classes: &[u32]) {
    debug_assert!(
        {
            let mut seen =
                std::collections::HashSet::with_capacity(classes.len());
            classes.iter().all(|c| seen.insert(*c))
        },
        "update_classes: duplicate class ids"
    );
}

/// One serving query against a pinned sampler state — the unit the
/// [`crate::serving`] micro-batcher coalesces and the
/// [`crate::transport`] wire protocol carries. Each variant pairs with a
/// row of the wave's query matrix; sample queries carry their own seed so
/// served draws are deterministic regardless of coalescing, thread
/// schedule, or which process the request came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeQuery {
    /// Draw `m` classes i.i.d. from `q(· | h)` on an RNG stream derived
    /// only from `seed`.
    Sample { m: usize, seed: u64 },
    /// Exact `q(class | h)`.
    Probability { class: usize },
    /// The `k` most probable classes under `q(· | h)`, descending.
    TopK { k: usize },
}

/// Answer to one [`ServeQuery`], variant-matched to the query kind.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeAnswer {
    Sample(NegativeDraw),
    Probability(f64),
    TopK(Vec<(u32, f64)>),
}

/// Per-wave cost attribution from [`Sampler::serve_queries_traced`]:
/// nanoseconds spent in the batched feature-map gemm (`φ` of every
/// query row) versus the per-row tree walks / probability reads that
/// consume it. Samplers without a gemm/walk split report the whole
/// serve cost as `walk_ns`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeTrace {
    /// Time in the batched kernel feature map (one gemm per wave).
    pub gemm_ns: u64,
    /// Time in per-row tree walks / rankings / probability lookups.
    pub walk_ns: u64,
}

/// Result of drawing `m` classes: ids plus their exact sampling
/// probabilities under the sampler's distribution (conditioned on the
/// excluded target when drawn via [`Sampler::sample_negatives`]).
#[derive(Clone, Debug, PartialEq)]
pub struct NegativeDraw {
    pub ids: Vec<u32>,
    pub probs: Vec<f64>,
}

impl NegativeDraw {
    pub fn with_capacity(m: usize) -> Self {
        Self { ids: Vec::with_capacity(m), probs: Vec::with_capacity(m) }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

/// Result of a batched negative draw: one [`NegativeDraw`] per example
/// (row of the query matrix), each of `m` classes conditioned on
/// `≠ targets[b]` with exact per-example probabilities.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchDraw {
    pub draws: Vec<NegativeDraw>,
}

impl BatchDraw {
    /// Number of examples.
    pub fn batch(&self) -> usize {
        self.draws.len()
    }

    /// Negatives per example (0 for an empty batch).
    pub fn m(&self) -> usize {
        self.draws.first().map_or(0, NegativeDraw::len)
    }

    /// Total draws across the batch.
    pub fn total(&self) -> usize {
        self.draws.iter().map(NegativeDraw::len).sum()
    }

    /// Flattened `batch × m` ids, row-major.
    pub fn flat_ids(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.total());
        for d in &self.draws {
            out.extend_from_slice(&d.ids);
        }
        out
    }
}

/// A (possibly input-dependent) sampling distribution over classes.
///
/// ## Mutable class universe
///
/// Samplers may support runtime growth ([`Sampler::add_classes`]) and
/// shrinkage ([`Sampler::retire_classes`]). The contract:
///
/// * slot ids are **stable**: adding appends new ids
///   `num_classes()..num_classes()+k`, retiring leaves a permanent hole
///   (ids are never reused), so trained embedding tables never need
///   re-indexing;
/// * retired slots are **masked out**, not left as zero-probability
///   support: `sample*`/`serve_queries`/`top_k` never emit them (even
///   through rejection fallbacks) and `probability` returns an exact 0;
/// * mutations are amortized `O(D log n)` for the kernel samplers
///   (capacity doubling only — never a full-tree rebuild on the hot
///   path).
///
/// Fixed-universe samplers (the default) answer every mutation with a
/// typed [`VocabError`].
pub trait Sampler: Send {
    /// Total number of class slots n (live + retired holes).
    fn num_classes(&self) -> usize;

    /// Live (non-retired) classes — the support of the distribution.
    /// Equals [`Sampler::num_classes`] for fixed-universe samplers.
    fn live_classes(&self) -> usize {
        self.num_classes()
    }

    /// Append `embeddings.rows()` new classes (row `k` becomes class
    /// `num_classes() + k`), returning the assigned ids. Default: a
    /// typed error for fixed-universe samplers.
    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        let _ = embeddings;
        Err(VocabError::fixed(self.name()))
    }

    /// Retire the given live classes: their slots become permanent holes
    /// that are never emitted again. Ids must be live and duplicate-free.
    /// Default: a typed error for fixed-universe samplers.
    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        let _ = classes;
        Err(VocabError::fixed(self.name()))
    }

    /// Draw `m` classes i.i.d. from `q(· | h)`, returning exact
    /// probabilities. `h` is the current input embedding (ignored by
    /// static samplers).
    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw;

    /// Exact probability `q_i(h)` of class `i`.
    fn probability(&self, h: &[f32], class: usize) -> f64;

    /// Total unnormalized proposal mass `M(h)` — the normalizer the
    /// per-class masses `q_i(h) · M(h)` are divided by. Serving clusters
    /// use it to merge draws across replicas holding disjoint class
    /// shards: with each replica advertising its own `M_r(h)`, picking a
    /// replica ∝ `M_r(h)` and a class within it from `q^(r)(· | h)`
    /// reproduces the union distribution exactly. The default, `live
    /// classes`, is exact for uniform samplers (unit mass per live
    /// class); kernel samplers override it with their tree root mass.
    fn root_mass(&self, h: &[f32]) -> f64 {
        let _ = h;
        self.live_classes() as f64
    }

    /// Draw `m` *negatives*: classes i.i.d. from `q(· | h)` conditioned on
    /// `≠ target`, with probabilities renormalized by `1 − q_target`
    /// (rejection sampling; exact).
    ///
    /// Termination is unconditional: if `q_target ≈ 1` (or rejection
    /// fails to fill `m` slots within [`REJECTION_ROUNDS`] rounds, which
    /// implies the same degeneracy), the remaining slots fall back to a
    /// uniform draw over the `n − 1` non-target classes with the exact
    /// fallback probability `1/(n − 1)`. Each slot reports the pmf of the
    /// mechanism that actually produced it, so the importance-weighted
    /// partition estimate (paper eq. 5) stays well-defined — production
    /// runs never abort.
    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        let n = self.num_classes();
        assert!(n > 1, "sample_negatives: need ≥ 2 classes to exclude one");
        let q_t = self.probability(h, target);
        let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);
        let mut out = NegativeDraw::with_capacity(m);
        let mut rounds = 0usize;
        while out.ids.len() < m && rounds < REJECTION_ROUNDS && q_t < DEGENERATE_Q {
            let draw = self.sample(h, m - out.ids.len(), rng);
            for (id, p) in draw.ids.iter().zip(draw.probs.iter()) {
                if *id as usize != target {
                    out.ids.push(*id);
                    out.probs.push(p / renorm);
                }
            }
            rounds += 1;
        }
        while out.ids.len() < m {
            out.ids.push(uniform_excluding(n, target, rng) as u32);
            out.probs.push(1.0 / (n - 1) as f64);
        }
        out
    }

    /// Batched negative draw: row `b` of `h` is example b's query and
    /// `targets[b]` is excluded from its `m` draws, with exact
    /// per-example probabilities preserved.
    ///
    /// Default implementation loops [`Sampler::sample_negatives`] per
    /// row; kernel samplers override it with one batched feature map
    /// (`φ` of every query in a single gemm) and tree walks fanned out
    /// across the [`crate::exec`] substrate.
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        assert_eq!(h.rows(), targets.len(), "sample_batch: batch mismatch");
        let draws = (0..h.rows())
            .map(|b| {
                self.sample_negatives(h.row(b), targets[b] as usize, m, rng)
            })
            .collect();
        BatchDraw { draws }
    }

    /// Unconditioned batched draw for *shared* negative pools: row `b`
    /// contributes `m` i.i.d. draws from `q(· | h_b)` with exact
    /// (unconditioned) probabilities — no target exclusion, matching the
    /// classic shared-negative contract where accidental hits against any
    /// example's target are handled by the coordinator's logit mask.
    /// Keeping the proposal's support full is what keeps the eq.-5
    /// partition estimate unbiased for *every* example in the batch, not
    /// just the slot's owner.
    fn sample_batch_shared(
        &self,
        h: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        let draws = (0..h.rows())
            .map(|b| self.sample(h.row(b), m, rng))
            .collect();
        BatchDraw { draws }
    }

    /// Mixed-kind serving wave ([`crate::serving`] micro-batcher): row
    /// `b` of `h` answers `queries[b]` — a sample draw (on an RNG stream
    /// derived *only* from the request's seed), an exact probability, or
    /// a top-k ranking. Because no randomness is shared across rows, a
    /// request's answer depends on nothing but its query and the sampler
    /// state — not on which other requests it was coalesced with or on
    /// thread scheduling. Kernel samplers override with one `map_batch`
    /// gemm for the whole wave *regardless of query kind*, plus per-row
    /// φ-level tree operations fanned out on the persistent serve pool.
    ///
    /// The answer vector is index- and kind-aligned with `queries`.
    fn serve_queries(&self, h: &Matrix, queries: &[ServeQuery]) -> Vec<ServeAnswer> {
        assert_eq!(h.rows(), queries.len(), "serve_queries: length mismatch");
        (0..h.rows())
            .map(|b| match queries[b] {
                ServeQuery::Sample { m, seed } => {
                    let mut rng = Rng::seeded(seed);
                    ServeAnswer::Sample(self.sample(h.row(b), m, &mut rng))
                }
                ServeQuery::Probability { class } => {
                    ServeAnswer::Probability(self.probability(h.row(b), class))
                }
                ServeQuery::TopK { k } => {
                    ServeAnswer::TopK(self.top_k(h.row(b), k))
                }
            })
            .collect()
    }

    /// [`Sampler::serve_queries`] with per-stage cost attribution for
    /// the live-telemetry pipeline: `trace` accumulates the wave's gemm
    /// (batched feature map) and tree-walk nanoseconds. The default —
    /// correct for samplers with no batched feature map — times the
    /// whole call as walk work; [`ShardedKernelSampler`] overrides it
    /// to split `map_batch` from the fanned-out walks.
    fn serve_queries_traced(
        &self,
        h: &Matrix,
        queries: &[ServeQuery],
        trace: &mut ServeTrace,
    ) -> Vec<ServeAnswer> {
        let t0 = std::time::Instant::now();
        let out = self.serve_queries(h, queries);
        trace.walk_ns += t0.elapsed().as_nanos() as u64;
        out
    }

    /// Sample-only serving batch: row `b` of `h` draws `ms[b]` classes
    /// i.i.d. from `q(· | h_b)` with exact unconditioned probabilities,
    /// seeded per row. A thin wrapper over [`Sampler::serve_queries`], so
    /// overriding that one method is enough to accelerate both entries.
    fn serve_batch(
        &self,
        h: &Matrix,
        ms: &[usize],
        seeds: &[u64],
    ) -> Vec<NegativeDraw> {
        assert_eq!(h.rows(), ms.len(), "serve_batch: ms mismatch");
        assert_eq!(h.rows(), seeds.len(), "serve_batch: seeds mismatch");
        let queries: Vec<ServeQuery> = ms
            .iter()
            .zip(seeds)
            .map(|(&m, &seed)| ServeQuery::Sample { m, seed })
            .collect();
        self.serve_queries(h, &queries)
            .into_iter()
            .map(|a| match a {
                ServeAnswer::Sample(d) => d,
                _ => unreachable!("sample query answered with non-sample kind"),
            })
            .collect()
    }

    /// The `k` most probable classes under `q(· | h)`, descending (ties
    /// broken by class id). Default scans all `n` probabilities; kernel
    /// samplers override with a best-first tree search. `k` clamps to
    /// the live count, and in a universe with holes the zero-mass
    /// retired slots are filtered so they can never pad the tail.
    fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        let n = self.num_classes();
        let live = self.live_classes();
        let k = k.min(live);
        let mut all: Vec<(u32, f64)> = (0..n)
            .map(|i| (i as u32, self.probability(h, i)))
            .filter(|&(_, q)| live == n || q > 0.0)
            .collect();
        all.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        all.truncate(k);
        all
    }

    /// Deep-copy this sampler into an independently owned, thread-shareable
    /// copy — the [`crate::serving`] snapshot/shadow hook. The fork must
    /// reproduce the same distribution `q(· | h)` as `self` and keep
    /// tracking it under subsequent `update_classes` calls. Returns `None`
    /// for samplers that cannot be served (the default).
    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        None
    }

    /// Propagate an updated class embedding into the sampler's state
    /// (no-op for input-independent samplers).
    fn update_class(&mut self, class: usize, embedding: &[f32]);

    /// Batched class propagation: class `classes[k]` takes the embedding
    /// in `embeddings.row(k)`. Ids must be unique (the coordinator's
    /// gradient aggregation guarantees this). Default applies serially;
    /// [`ShardedKernelSampler`] overrides with batched φ recomputation
    /// and shard-parallel tree updates.
    fn update_classes(&mut self, classes: &[u32], embeddings: &Matrix) {
        assert_eq!(
            classes.len(),
            embeddings.rows(),
            "update_classes: ids/rows mismatch"
        );
        for (k, &c) in classes.iter().enumerate() {
            self.update_class(c as usize, embeddings.row(k));
        }
    }

    /// Human-readable name (matches the paper's method labels).
    fn name(&self) -> &'static str;

    /// Capture the sampler's full durable state ([`crate::snapshot`]):
    /// tree sums, slot tables, live set, quantized class store. `None`
    /// for samplers without snapshot support (the default) — e.g. the
    /// MIDX backend until it grows a codec of its own.
    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        None
    }

    /// Replace this sampler's state with a captured snapshot. The
    /// receiver acts as a *skeleton*: it must have been built with the
    /// same feature map + config (fingerprint-checked for kernel
    /// samplers), but its class content is discarded wholesale — that
    /// is what makes restore `O(state)` instead of `O(n · D)` rebuild.
    /// Kind mismatches and map mismatches are typed errors; partially
    /// applied restores never escape (implementations swap state in
    /// only after all validation passes).
    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        let _ = state;
        Err(crate::snapshot::SnapshotError::Unsupported(self.name()))
    }
}

/// A sampler whose shared state may be read from many threads at once —
/// what the [`crate::serving`] layer stores inside its snapshots. The
/// blanket impl covers every `Sampler + Sync` type; `!Sync` samplers
/// (e.g. the scratch-caching unsharded kernel sampler) instead `fork`
/// into an equivalent `Sync` representation.
pub trait ServeSampler: Sampler + Sync {
    /// View as a plain `&dyn Sampler` (kept explicit so the crate does
    /// not depend on trait-object upcasting).
    fn as_sampler(&self) -> &dyn Sampler;
}

impl<T: Sampler + Sync> ServeSampler for T {
    fn as_sampler(&self) -> &dyn Sampler {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Chi-square goodness-of-fit of empirical draws vs claimed probs.
    /// Shared across sampler tests via pub(crate).
    pub(crate) fn chi2_check(
        sampler: &dyn Sampler,
        h: &[f32],
        trials: usize,
        rng: &mut Rng,
        tol_sigma: f64,
    ) {
        let n = sampler.num_classes();
        let mut counts = vec![0usize; n];
        let draw = sampler.sample(h, trials, rng);
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
        for i in 0..n {
            let q = sampler.probability(h, i);
            let expect = q * trials as f64;
            let sd = (trials as f64 * q * (1.0 - q)).sqrt().max(1.0);
            assert!(
                (counts[i] as f64 - expect).abs() <= tol_sigma * sd + 3.0,
                "class {i}: count {} vs expected {expect:.1} (q={q:.5})",
                counts[i]
            );
        }
    }

    #[test]
    fn negative_draw_capacity() {
        let d = NegativeDraw::with_capacity(5);
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
    }

    /// Pathological sampler: all probability mass on one class. The old
    /// rejection loop panicked after 10k rounds here; the fallback must
    /// return uniform-excluding-target draws instead.
    struct DegenerateSampler {
        n: usize,
        hot: usize,
    }

    impl Sampler for DegenerateSampler {
        fn num_classes(&self) -> usize {
            self.n
        }

        fn sample(&self, _h: &[f32], m: usize, _rng: &mut Rng) -> NegativeDraw {
            NegativeDraw {
                ids: vec![self.hot as u32; m],
                probs: vec![1.0; m],
            }
        }

        fn probability(&self, _h: &[f32], class: usize) -> f64 {
            if class == self.hot {
                1.0
            } else {
                0.0
            }
        }

        fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

        fn name(&self) -> &'static str {
            "degenerate"
        }
    }

    #[test]
    fn sample_negatives_falls_back_when_q_target_is_one() {
        let s = DegenerateSampler { n: 10, hot: 3 };
        let mut rng = Rng::seeded(120);
        let draw = s.sample_negatives(&[], 3, 40, &mut rng);
        assert_eq!(draw.len(), 40);
        assert!(draw.ids.iter().all(|&i| i != 3 && (i as usize) < 10));
        for &q in &draw.probs {
            assert!((q - 1.0 / 9.0).abs() < 1e-12, "fallback q = {q}");
        }
    }

    /// Sampler whose claimed `q_target` looks benign but whose draws
    /// always hit the target — exercises the round-cap escape hatch
    /// (as opposed to the `q_target ≈ 1` early exit above).
    struct StuckSampler {
        n: usize,
        target: usize,
    }

    impl Sampler for StuckSampler {
        fn num_classes(&self) -> usize {
            self.n
        }

        fn sample(&self, _h: &[f32], m: usize, _rng: &mut Rng) -> NegativeDraw {
            NegativeDraw {
                ids: vec![self.target as u32; m],
                probs: vec![0.5; m],
            }
        }

        fn probability(&self, _h: &[f32], _class: usize) -> f64 {
            0.5
        }

        fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

        fn name(&self) -> &'static str {
            "stuck"
        }
    }

    #[test]
    fn sample_negatives_falls_back_when_rejection_cannot_fill() {
        let s = StuckSampler { n: 4, target: 0 };
        let mut rng = Rng::seeded(121);
        let draw = s.sample_negatives(&[], 0, 12, &mut rng);
        assert_eq!(draw.len(), 12);
        assert!(draw.ids.iter().all(|&i| i != 0));
        assert!(draw.probs.iter().all(|&q| (q - 1.0 / 3.0).abs() < 1e-12));
    }

    #[test]
    fn default_sample_batch_excludes_per_example_targets() {
        let s = super::UniformSampler::new(16);
        let mut rng = Rng::seeded(122);
        let mut h = Matrix::zeros(3, 2);
        for b in 0..3 {
            h.row_mut(b).copy_from_slice(&[b as f32, 1.0]);
        }
        let targets = [2u32, 5, 9];
        let batch = s.sample_batch(&h, &targets, 25, &mut rng);
        assert_eq!(batch.batch(), 3);
        assert_eq!(batch.m(), 25);
        assert_eq!(batch.total(), 75);
        assert_eq!(batch.flat_ids().len(), 75);
        for (b, d) in batch.draws.iter().enumerate() {
            assert_eq!(d.len(), 25);
            assert!(d.ids.iter().all(|&i| i != targets[b]));
            // Uniform conditioned on ≠ target: q = (1/16)/(15/16) = 1/15.
            for &q in &d.probs {
                assert!((q - 1.0 / 15.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn uniform_excluding_covers_all_non_targets() {
        let mut rng = Rng::seeded(123);
        let mut seen = [false; 7];
        for _ in 0..2000 {
            let i = uniform_excluding(7, 4, &mut rng);
            assert!(i < 7 && i != 4);
            seen[i] = true;
        }
        for (i, &s) in seen.iter().enumerate() {
            assert!(s || i == 4, "class {i} never drawn");
        }
    }
}
