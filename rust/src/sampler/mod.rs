//! Negative-sampling distributions for sampled softmax (paper §1.1, §3).
//!
//! A [`Sampler`] produces `m` class indices with their exact sampling
//! probabilities `q_i` — the probabilities feed the logit adjustment
//! `o′ = o − log(m·q)` (paper eq. 5) that makes the sampled partition
//! function unbiased.
//!
//! The paper's taxonomy, reproduced here:
//!
//! | Sampler | q_i | cost/sample | paper role |
//! |---|---|---|---|
//! | [`RffSampler`] | `∝ φ_RFF(c_i)ᵀφ_RFF(h)` | `O(D log n)` | **RF-softmax (the contribution)** |
//! | [`QuadraticSampler`] | `∝ α(hᵀc_i)²+β` | `O(d² log n)` | Quadratic-softmax baseline [12] |
//! | [`ExactSoftmaxSampler`] | `∝ e^{τhᵀc_i}` | `O(dn)` | EXP baseline |
//! | [`UniformSampler`] | `1/n` | `O(1)` | UNIFORM baseline |
//! | [`LogUniformSampler`] | `∝ log((i+2)/(i+1))` | `O(1)` | classic LM prior |
//! | [`AliasSampler`] | arbitrary static pmf | `O(1)` | unigram prior |
//! | [`GumbelTopKSampler`] | top-k of perturbed logits | `O(dn)` | Gumbel-trick extension [13] |
//!
//! Kernel-based samplers run on the [`KernelTree`] divide-and-conquer
//! structure of §3.1 and support `O(D log n)` embedding updates.

mod bucket;
mod kernel_samplers;
mod simple;
mod tree;

pub use bucket::BucketKernelSampler;
pub use kernel_samplers::{QuadraticSampler, RffSampler};
pub use simple::{
    AliasSampler, ExactSoftmaxSampler, GumbelTopKSampler, LogUniformSampler,
    UniformSampler,
};
pub use tree::KernelTree;

use crate::rng::Rng;

/// Result of drawing `m` classes: ids plus their exact sampling
/// probabilities under the sampler's distribution (conditioned on the
/// excluded target when drawn via [`Sampler::sample_negatives`]).
#[derive(Clone, Debug, PartialEq)]
pub struct NegativeDraw {
    pub ids: Vec<u32>,
    pub probs: Vec<f64>,
}

impl NegativeDraw {
    pub fn with_capacity(m: usize) -> Self {
        Self { ids: Vec::with_capacity(m), probs: Vec::with_capacity(m) }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }
}

/// A (possibly input-dependent) sampling distribution over classes.
pub trait Sampler: Send {
    /// Total number of classes n.
    fn num_classes(&self) -> usize;

    /// Draw `m` classes i.i.d. from `q(· | h)`, returning exact
    /// probabilities. `h` is the current input embedding (ignored by
    /// static samplers).
    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw;

    /// Exact probability `q_i(h)` of class `i`.
    fn probability(&self, h: &[f32], class: usize) -> f64;

    /// Draw `m` *negatives*: classes i.i.d. from `q(· | h)` conditioned on
    /// `≠ target`, with probabilities renormalized by `1 − q_target`
    /// (rejection sampling; exact).
    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        let q_t = self.probability(h, target);
        let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);
        let mut out = NegativeDraw::with_capacity(m);
        let mut guard = 0usize;
        while out.ids.len() < m {
            let draw = self.sample(h, m - out.ids.len(), rng);
            for (id, p) in draw.ids.iter().zip(draw.probs.iter()) {
                if *id as usize != target {
                    out.ids.push(*id);
                    out.probs.push(p / renorm);
                }
            }
            guard += 1;
            assert!(
                guard < 10_000,
                "sample_negatives: rejection not terminating (q_target={q_t})"
            );
        }
        out
    }

    /// Propagate an updated class embedding into the sampler's state
    /// (no-op for input-independent samplers).
    fn update_class(&mut self, class: usize, embedding: &[f32]);

    /// Human-readable name (matches the paper's method labels).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Chi-square goodness-of-fit of empirical draws vs claimed probs.
    /// Shared across sampler tests via pub(crate).
    pub(crate) fn chi2_check(
        sampler: &dyn Sampler,
        h: &[f32],
        trials: usize,
        rng: &mut Rng,
        tol_sigma: f64,
    ) {
        let n = sampler.num_classes();
        let mut counts = vec![0usize; n];
        let draw = sampler.sample(h, trials, rng);
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
        for i in 0..n {
            let q = sampler.probability(h, i);
            let expect = q * trials as f64;
            let sd = (trials as f64 * q * (1.0 - q)).sqrt().max(1.0);
            assert!(
                (counts[i] as f64 - expect).abs() <= tol_sigma * sd + 3.0,
                "class {i}: count {} vs expected {expect:.1} (q={q:.5})",
                counts[i]
            );
        }
    }

    #[test]
    fn negative_draw_capacity() {
        let d = NegativeDraw::with_capacity(5);
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
    }
}
