//! Kernel-based samplers: the paper's RF-softmax and the Quadratic-softmax
//! baseline, both running on the [`KernelTree`].
//!
//! The sampler owns (a) the feature map φ, (b) a copy of the class
//! embeddings (needed to recompute `φ_old` on updates — this keeps tree
//! memory at `O(nD)` node sums instead of additionally storing every leaf
//! feature vector), and (c) reusable query scratch.

use super::{
    BatchDraw, KernelTree, NegativeDraw, Sampler, ServeSampler, VocabError,
};
use crate::config::FeatureMapKind;
use crate::featmap::{FeatureMap, OrfMap, QuadraticMap, RffMap, SorfMap};
use crate::linalg::{ClassStore, Matrix, QuantizeKind};
use crate::rng::Rng;
use std::cell::RefCell;

/// Probability floor fed to the tree; keeps every q_i strictly positive
/// (Theorem 1's requirement) while being negligible against real kernel
/// mass (RFF values are O(1) for normalized embeddings).
const TREE_EPS: f64 = 1e-8;

/// Generic kernel sampler over an arbitrary feature map.
pub struct KernelSampler<M: FeatureMap> {
    map: M,
    tree: KernelTree,
    /// Copy of current class embeddings (n × d), in the configured
    /// `sampler.quantize` precision. Every φ in the tree is computed
    /// from the *dequantized* stored row (build, add, update, retire),
    /// so interior sums are consistently sums of `φ(deq(quant(c)))` —
    /// quantization perturbs the universe slightly, never the tree's
    /// internal bookkeeping.
    classes: ClassStore,
    /// Scratch for φ computations (avoids per-call allocation).
    scratch: RefCell<Scratch>,
    name: &'static str,
}

struct Scratch {
    query: Vec<f32>,
    phi_old: Vec<f32>,
    phi_new: Vec<f32>,
    /// Dequantized embedding-row buffer (input dim d, not feature dim).
    row: Vec<f32>,
}

impl<M: FeatureMap> KernelSampler<M> {
    pub fn with_map(classes: &Matrix, map: M, name: &'static str) -> Self {
        Self::with_map_opts(classes, map, name, 0, QuantizeKind::None)
    }

    /// Full-option constructor: `capacity` pre-reserves tree padding for
    /// a planned universe size (`sampler.max_capacity`; 0 = none), and
    /// `quantize` selects the storage precision of the private class
    /// copy (`sampler.quantize`).
    pub fn with_map_opts(
        classes: &Matrix,
        map: M,
        name: &'static str,
        capacity: usize,
        quantize: QuantizeKind,
    ) -> Self {
        let n = classes.rows();
        let d = classes.cols();
        let dim = map.output_dim();
        assert_eq!(
            d,
            map.input_dim(),
            "class embedding dim must match feature-map input dim"
        );
        let store = ClassStore::from_matrix(classes, quantize);
        let mut tree = KernelTree::with_capacity(n, dim, TREE_EPS, capacity);
        let mut row = vec![0.0f32; d];
        let mut phi = vec![0.0f32; dim];
        for i in 0..n {
            store.row_into(i, &mut row);
            map.map_into(&row, &mut phi);
            tree.add_leaf(i, &phi);
        }
        Self {
            map,
            tree,
            classes: store,
            scratch: RefCell::new(Scratch {
                query: vec![0.0; dim],
                phi_old: vec![0.0; dim],
                phi_new: vec![0.0; dim],
                row: vec![0.0; d],
            }),
            name,
        }
    }

    /// The tree's memory footprint (for the Table-2 harness notes).
    /// The class-copy term shrinks 2×/4× under f16/i8 quantization.
    pub fn memory_bytes(&self) -> usize {
        self.tree.memory_bytes() + self.classes.memory_bytes()
    }

    /// Storage precision of the private class copy.
    pub fn quantize(&self) -> QuantizeKind {
        self.classes.kind()
    }

    /// Capacity-doubling copies the tree has paid (0 when `capacity`
    /// pre-reservation covered the growth schedule).
    pub fn growths(&self) -> usize {
        self.tree.growths()
    }

    pub fn feature_map(&self) -> &M {
        &self.map
    }

    /// Rebuild the tree from scratch (counters numerical drift after very
    /// long runs; `O(nD + nd·cost(φ))`). Preserves retired holes.
    pub fn rebuild(&mut self) {
        let n = self.classes.rows();
        let dim = self.map.output_dim();
        let mut tree = KernelTree::new(n, dim, TREE_EPS);
        let mut row = vec![0.0f32; self.classes.cols()];
        let mut phi = vec![0.0f32; dim];
        for i in 0..n {
            if self.tree.is_retired(i) {
                continue; // leave the hole's leaf at exactly zero
            }
            self.classes.row_into(i, &mut row);
            self.map.map_into(&row, &mut phi);
            tree.add_leaf(i, &phi);
        }
        let zeros = vec![0.0f32; dim];
        for i in 0..n {
            if self.tree.is_retired(i) {
                // Re-tombstone: the fresh leaf holds no mass, so the
                // subtraction is of a zero vector.
                tree.retire_class(i, &zeros);
            }
        }
        self.tree = tree;
    }

    /// Slot ids currently retired (holes), ascending.
    fn retired_ids(&self) -> Vec<u32> {
        (0..self.tree.num_classes() as u32)
            .filter(|&i| self.tree.is_retired(i as usize))
            .collect()
    }
}

impl<M: FeatureMap + Clone + 'static> Sampler for KernelSampler<M> {
    fn num_classes(&self) -> usize {
        self.tree.num_classes()
    }

    fn live_classes(&self) -> usize {
        self.tree.live_classes()
    }

    /// Append new classes (amortized `O(D log n)` each: one path update
    /// plus the capacity-doubling copy amortized over the doubling).
    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        if embeddings.rows() == 0 {
            return Ok(Vec::new());
        }
        super::validate_add_dim(embeddings.cols(), self.classes.cols())?;
        // Ingest first, then φ from the *dequantized* stored rows, so the
        // tree's leaf mass matches what updates/retires will later
        // recompute from the store.
        let base = self.classes.rows();
        let k = embeddings.rows();
        for r in 0..k {
            self.classes.push_row(embeddings.row(r));
        }
        let new_ids: Vec<u32> = (base..base + k).map(|i| i as u32).collect();
        let deq = self.classes.gather_rows(&new_ids);
        let phis = self.map.map_batch(&deq);
        let mut ids = Vec::with_capacity(k);
        for r in 0..k {
            let g = self.tree.insert_class(phis.row(r));
            debug_assert_eq!(g, base + r);
            ids.push(g as u32);
        }
        Ok(ids)
    }

    /// Retire live classes (`O(D log n)` each); validated up front, with
    /// φ of every victim from one `map_batch` gemm.
    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        super::validate_retire(
            classes,
            self.tree.num_classes(),
            self.tree.live_classes(),
            |c| self.tree.is_retired(c),
        )?;
        let (map, cls, tree) = (&self.map, &self.classes, &mut self.tree);
        super::retire_phi_batch(map, cls, classes, |c, phi| {
            tree.retire_class(c, phi)
        });
        Ok(())
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let mut sc = self.scratch.borrow_mut();
        self.map.map_into(h, &mut sc.query);
        let (ids, probs) = self.tree.sample_many(&sc.query, m, rng);
        NegativeDraw { ids, probs }
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        let mut sc = self.scratch.borrow_mut();
        self.map.map_into(h, &mut sc.query);
        self.tree.probability(&sc.query, class)
    }

    /// Exact normalizer of this sampler's `probability`: the tree's
    /// effective root mass at φ(h).
    fn root_mass(&self, h: &[f32]) -> f64 {
        let mut sc = self.scratch.borrow_mut();
        self.map.map_into(h, &mut sc.query);
        self.tree.effective_mass(&sc.query)
    }

    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        // Map φ(h) once; the trait default would re-map on every
        // rejection round and for the q_target query.
        let mut sc = self.scratch.borrow_mut();
        self.map.map_into(h, &mut sc.query);
        let (ids, probs) = self.tree.sample_negatives(&sc.query, target, m, rng);
        NegativeDraw { ids, probs }
    }

    /// Batch draw: φ of every query in one [`FeatureMap::map_batch`]
    /// gemm, then per-example tree walks fanned out via
    /// [`super::fan_out_draws`]. The tree is shared read-only; the
    /// `RefCell` scratch is not touched on this path.
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        let bsz = h.rows();
        assert_eq!(bsz, targets.len(), "sample_batch: batch mismatch");
        let queries = self.map.map_batch(h);
        let tree = &self.tree;
        let draws = super::fan_out_draws(bsz, m, rng, |b, r| {
            let (ids, probs) =
                tree.sample_negatives(queries.row(b), targets[b] as usize, m, r);
            NegativeDraw { ids, probs }
        });
        BatchDraw { draws }
    }

    /// Unconditioned batch draw (shared-pool contract): same gemm +
    /// fan-out, walks via the memoized [`KernelTree::sample_many`].
    fn sample_batch_shared(
        &self,
        h: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        let bsz = h.rows();
        let queries = self.map.map_batch(h);
        let tree = &self.tree;
        let draws = super::fan_out_draws(bsz, m, rng, |b, r| {
            let (ids, probs) = tree.sample_many(queries.row(b), m, r);
            NegativeDraw { ids, probs }
        });
        BatchDraw { draws }
    }

    /// Mixed-kind serving wave: one `map_batch` gemm regardless of query
    /// kind, then per-row φ-level tree operations via
    /// [`super::fan_out_queries`] on the persistent serve pool (no
    /// scratch `RefCell` on this path, so it is safe regardless of how
    /// the caller fans rows out). Also powers `serve_batch` through the
    /// trait-level wrapper.
    fn serve_queries(
        &self,
        h: &Matrix,
        queries: &[super::ServeQuery],
    ) -> Vec<super::ServeAnswer> {
        assert_eq!(h.rows(), queries.len(), "serve_queries: length mismatch");
        let phi = self.map.map_batch(h);
        let tree = &self.tree;
        super::fan_out_queries(queries, |b| match queries[b] {
            super::ServeQuery::Sample { m, seed } => {
                let mut rng = Rng::seeded(seed);
                let (ids, probs) = tree.sample_many(phi.row(b), m, &mut rng);
                super::ServeAnswer::Sample(NegativeDraw { ids, probs })
            }
            super::ServeQuery::Probability { class } => {
                super::ServeAnswer::Probability(
                    tree.probability(phi.row(b), class),
                )
            }
            super::ServeQuery::TopK { k } => {
                super::ServeAnswer::TopK(tree.top_k(phi.row(b), k))
            }
        })
    }

    fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        // No scratch borrow: top_k is a serving-path query and must stay
        // usable while other threads hold the forked snapshot.
        let z = self.map.map(h);
        self.tree.top_k(&z, k)
    }

    /// Serving fork: this sampler's `RefCell` scratch makes it `!Sync`,
    /// so the fork rebuilds the same distribution on the naturally-`Sync`
    /// single-shard [`super::ShardedKernelSampler`] (identical tree
    /// semantics — a one-shard pick is a no-op — and the same `TREE_EPS`
    /// floor), then re-retires this sampler's holes so a churned
    /// universe forks faithfully. Note the fork's *draw stream* differs
    /// from the unsharded walk (the shard pick consumes RNG) even though
    /// the distribution is identical. `O(n · cost(φ))`, paid once at
    /// server construction.
    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        // Seed the fork from the dequantized store and re-apply the same
        // quantize kind: for f16 re-quantization is exactly idempotent
        // (dequant maps every code to a value that rounds back to itself)
        // so the fork's distribution is bit-faithful; i8 re-derives
        // per-row scales, which existing fork tests only exercise under
        // `QuantizeKind::None`.
        let mut fork = super::ShardedKernelSampler::with_map_opts(
            &self.classes.dequantized(),
            self.map.clone(),
            1,
            self.name,
            0,
            self.classes.kind(),
        );
        let retired = self.retired_ids();
        if !retired.is_empty() {
            fork.retire_classes(&retired)
                .expect("fork: re-retiring valid holes cannot fail");
        }
        Some(Box::new(fork))
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        // Both φ_old and φ_new come from dequantized *stored* rows (the
        // old row before `set_row`, the re-read row after), so the leaf
        // delta is consistent with how the leaf mass was first added.
        let sc = self.scratch.get_mut();
        self.classes.row_into(class, &mut sc.row);
        self.map.map_into(&sc.row, &mut sc.phi_old);
        self.classes.set_row(class, embedding);
        self.classes.row_into(class, &mut sc.row);
        self.map.map_into(&sc.row, &mut sc.phi_new);
        for (new, old) in sc.phi_new.iter_mut().zip(sc.phi_old.iter()) {
            *new -= old; // phi_new now holds the delta
        }
        self.tree.update_leaf(class, &sc.phi_new);
    }

    /// Batched propagation: φ_old / φ_new for all touched classes come
    /// from two `map_batch` gemms; the single tree then applies leaf
    /// deltas serially (shard-level write parallelism lives in
    /// [`super::ShardedKernelSampler`]).
    fn update_classes(&mut self, classes: &[u32], embeddings: &Matrix) {
        let k = classes.len();
        assert_eq!(k, embeddings.rows(), "update_classes: ids/rows mismatch");
        super::debug_assert_unique(classes);
        if k == 0 {
            return;
        }
        let phi_old = self.map.map_batch(&self.classes.gather_rows(classes));
        for (r, &c) in classes.iter().enumerate() {
            self.classes.set_row(c as usize, embeddings.row(r));
        }
        // Re-read the freshly-stored rows so φ_new reflects the
        // quantized values that future updates will see as "old".
        let phi_new = self.map.map_batch(&self.classes.gather_rows(classes));
        let mut delta = vec![0.0f32; self.tree.dim()];
        for r in 0..k {
            for ((dst, &a), &b) in delta
                .iter_mut()
                .zip(phi_new.row(r))
                .zip(phi_old.row(r))
            {
                *dst = a - b;
            }
            self.tree.update_leaf(classes[r] as usize, &delta);
        }
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        Some(crate::snapshot::SamplerState::Kernel(
            crate::snapshot::KernelState {
                map_fingerprint: crate::snapshot::map_fingerprint(&self.map),
                tree: self.tree.to_state(),
                classes: crate::snapshot::ClassStoreState::capture(
                    &self.classes,
                ),
            },
        ))
    }

    /// Restore into this sampler as a skeleton: the feature map must
    /// fingerprint-match the capture-time map (the tree's sums are sums
    /// of *that* map's φ values), but the current tree/classes content
    /// is discarded wholesale — build the skeleton from a single dummy
    /// row and restore replaces everything in `O(state)`.
    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        use crate::snapshot::{SamplerState, SnapshotError};
        let SamplerState::Kernel(k) = state else {
            return Err(SnapshotError::Unsupported(
                "kernel sampler cannot restore a non-kernel snapshot",
            ));
        };
        state.validate()?;
        let computed = crate::snapshot::map_fingerprint(&self.map);
        if computed != k.map_fingerprint {
            return Err(SnapshotError::MapMismatch {
                stored: k.map_fingerprint,
                computed,
            });
        }
        if k.tree.dim != self.map.output_dim() {
            return Err(SnapshotError::Malformed(
                "kernel restore: tree dim != map output dim",
            ));
        }
        if k.classes.cols() != self.map.input_dim() {
            return Err(SnapshotError::Malformed(
                "kernel restore: class cols != map input dim",
            ));
        }
        let tree = KernelTree::from_state(&k.tree)?;
        self.classes = k.classes.materialize();
        self.tree = tree;
        let (dim, d) = (self.map.output_dim(), self.map.input_dim());
        self.scratch = RefCell::new(Scratch {
            query: vec![0.0; dim],
            phi_old: vec![0.0; dim],
            phi_new: vec![0.0; dim],
            row: vec![0.0; d],
        });
        Ok(())
    }
}

// The scratch RefCell is only touched from &self methods on a single
// thread at a time; the coordinator gives each worker its own sampler
// clone or routes through &mut. RefCell is !Sync, so assert Send only.
unsafe impl<M: FeatureMap> Send for KernelSampler<M> {}

/// RF-softmax (the paper's method): RFF/ORF/SORF features of the Gaussian
/// kernel with parameter ν ⇒ `q_i ∝ exp(-ν‖c_i − h‖²/2) ∝ exp(ν hᵀc_i)`
/// for normalized embeddings (paper eq. 16, 19).
pub enum RffSampler {
    Classic(KernelSampler<RffMap>),
    Orf(KernelSampler<OrfMap>),
    Sorf(KernelSampler<SorfMap>),
}

impl RffSampler {
    /// `num_freqs` = D frequencies (map output dim is 2D), ν the Gaussian
    /// kernel parameter (paper recommends ν < τ; T = 1/√ν = 0.5 is the
    /// paper's best PTB setting).
    pub fn new(
        classes: &Matrix,
        num_freqs: usize,
        nu: f32,
        rng: &mut Rng,
    ) -> Self {
        Self::with_kind(classes, num_freqs, nu, FeatureMapKind::Rff, rng)
    }

    pub fn with_kind(
        classes: &Matrix,
        num_freqs: usize,
        nu: f32,
        kind: FeatureMapKind,
        rng: &mut Rng,
    ) -> Self {
        Self::with_kind_opts(
            classes,
            num_freqs,
            nu,
            kind,
            rng,
            0,
            QuantizeKind::None,
        )
    }

    /// [`RffSampler::with_kind`] plus the `sampler.max_capacity` tree
    /// pre-reservation and `sampler.quantize` storage precision.
    pub fn with_kind_opts(
        classes: &Matrix,
        num_freqs: usize,
        nu: f32,
        kind: FeatureMapKind,
        rng: &mut Rng,
        capacity: usize,
        quantize: QuantizeKind,
    ) -> Self {
        let d = classes.cols();
        match kind {
            FeatureMapKind::Rff => {
                RffSampler::Classic(KernelSampler::with_map_opts(
                    classes,
                    RffMap::new(d, num_freqs, nu, rng),
                    "rff",
                    capacity,
                    quantize,
                ))
            }
            FeatureMapKind::Orf => {
                RffSampler::Orf(KernelSampler::with_map_opts(
                    classes,
                    OrfMap::new(d, num_freqs, nu, rng),
                    "rff-orf",
                    capacity,
                    quantize,
                ))
            }
            FeatureMapKind::Sorf => {
                RffSampler::Sorf(KernelSampler::with_map_opts(
                    classes,
                    SorfMap::new(d, num_freqs, nu, rng),
                    "rff-sorf",
                    capacity,
                    quantize,
                ))
            }
        }
    }

    fn inner(&self) -> &dyn Sampler {
        match self {
            RffSampler::Classic(s) => s,
            RffSampler::Orf(s) => s,
            RffSampler::Sorf(s) => s,
        }
    }

    fn inner_mut(&mut self) -> &mut dyn Sampler {
        match self {
            RffSampler::Classic(s) => s,
            RffSampler::Orf(s) => s,
            RffSampler::Sorf(s) => s,
        }
    }

    pub fn memory_bytes(&self) -> usize {
        match self {
            RffSampler::Classic(s) => s.memory_bytes(),
            RffSampler::Orf(s) => s.memory_bytes(),
            RffSampler::Sorf(s) => s.memory_bytes(),
        }
    }

    /// Capacity-doubling copies the underlying tree has paid.
    pub fn growths(&self) -> usize {
        match self {
            RffSampler::Classic(s) => s.growths(),
            RffSampler::Orf(s) => s.growths(),
            RffSampler::Sorf(s) => s.growths(),
        }
    }

    /// Storage precision of the private class copy.
    pub fn quantize(&self) -> QuantizeKind {
        match self {
            RffSampler::Classic(s) => s.quantize(),
            RffSampler::Orf(s) => s.quantize(),
            RffSampler::Sorf(s) => s.quantize(),
        }
    }

    pub fn rebuild(&mut self) {
        match self {
            RffSampler::Classic(s) => s.rebuild(),
            RffSampler::Orf(s) => s.rebuild(),
            RffSampler::Sorf(s) => s.rebuild(),
        }
    }
}

impl Sampler for RffSampler {
    fn num_classes(&self) -> usize {
        self.inner().num_classes()
    }

    fn live_classes(&self) -> usize {
        self.inner().live_classes()
    }

    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        self.inner_mut().add_classes(embeddings)
    }

    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        self.inner_mut().retire_classes(classes)
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        self.inner().sample(h, m, rng)
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        self.inner().probability(h, class)
    }

    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        self.inner().sample_negatives(h, target, m, rng)
    }

    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        self.inner().sample_batch(h, targets, m, rng)
    }

    fn sample_batch_shared(
        &self,
        h: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        self.inner().sample_batch_shared(h, m, rng)
    }

    fn serve_queries(
        &self,
        h: &Matrix,
        queries: &[super::ServeQuery],
    ) -> Vec<super::ServeAnswer> {
        self.inner().serve_queries(h, queries)
    }

    fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        self.inner().top_k(h, k)
    }

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        self.inner().fork()
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        self.inner_mut().update_class(class, embedding)
    }

    fn update_classes(&mut self, classes: &[u32], embeddings: &Matrix) {
        self.inner_mut().update_classes(classes, embeddings)
    }

    fn name(&self) -> &'static str {
        self.inner().name()
    }

    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        self.inner().snapshot_state()
    }

    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        self.inner_mut().restore_state(state)
    }
}

/// Quadratic-softmax baseline [12]: `q_i ∝ α(hᵀc_i)² + β` via the exact
/// `D = d²+1` linearization. Cost `O(d² log n)` per draw.
pub struct QuadraticSampler {
    inner: KernelSampler<QuadraticMap>,
}

impl QuadraticSampler {
    /// The paper's baseline setting is α = 100, β = 1.
    pub fn new(classes: &Matrix, alpha: f32, beta: f32) -> Self {
        Self::new_opts(classes, alpha, beta, 0, QuantizeKind::None)
    }

    /// [`QuadraticSampler::new`] plus tree pre-reservation and storage
    /// precision (`sampler.max_capacity` / `sampler.quantize`).
    pub fn new_opts(
        classes: &Matrix,
        alpha: f32,
        beta: f32,
        capacity: usize,
        quantize: QuantizeKind,
    ) -> Self {
        let map = QuadraticMap::new(classes.cols(), alpha, beta);
        Self {
            inner: KernelSampler::with_map_opts(
                classes,
                map,
                "quadratic",
                capacity,
                quantize,
            ),
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.inner.memory_bytes()
    }

    /// Capacity-doubling copies the underlying tree has paid.
    pub fn growths(&self) -> usize {
        self.inner.growths()
    }

    /// Storage precision of the private class copy.
    pub fn quantize(&self) -> QuantizeKind {
        self.inner.quantize()
    }
}

impl Sampler for QuadraticSampler {
    fn num_classes(&self) -> usize {
        self.inner.num_classes()
    }

    fn live_classes(&self) -> usize {
        self.inner.live_classes()
    }

    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        self.inner.add_classes(embeddings)
    }

    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        self.inner.retire_classes(classes)
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        self.inner.sample(h, m, rng)
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        self.inner.probability(h, class)
    }

    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        self.inner.sample_negatives(h, target, m, rng)
    }

    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        self.inner.sample_batch(h, targets, m, rng)
    }

    fn sample_batch_shared(
        &self,
        h: &Matrix,
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        self.inner.sample_batch_shared(h, m, rng)
    }

    fn serve_queries(
        &self,
        h: &Matrix,
        queries: &[super::ServeQuery],
    ) -> Vec<super::ServeAnswer> {
        self.inner.serve_queries(h, queries)
    }

    fn top_k(&self, h: &[f32], k: usize) -> Vec<(u32, f64)> {
        self.inner.top_k(h, k)
    }

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        self.inner.fork()
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        self.inner.update_class(class, embedding)
    }

    fn update_classes(&mut self, classes: &[u32], embeddings: &Matrix) {
        self.inner.update_classes(classes, embeddings)
    }

    fn name(&self) -> &'static str {
        "quadratic"
    }

    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        self.inner.snapshot_state()
    }

    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        self.inner.restore_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;

    fn normalized_classes(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        Matrix::randn(rng, n, d).l2_normalized_rows()
    }

    #[test]
    fn rff_sampler_tracks_softmax_distribution() {
        // With ν = τ and large D, q should correlate strongly with the
        // softmax distribution p ∝ exp(τ hᵀc) (paper Theorem 2).
        let mut rng = Rng::seeded(101);
        let n = 64;
        let d = 16;
        let tau = 2.0f32;
        let classes = normalized_classes(&mut rng, n, d);
        let sampler = RffSampler::new(&classes, 2048, tau, &mut rng);
        let h = unit_vector(&mut rng, d);
        let logits: Vec<f64> = (0..n)
            .map(|i| (tau * crate::linalg::dot(&h, classes.row(i))) as f64)
            .collect();
        let p = crate::linalg::softmax(&logits);
        let q: Vec<f64> = (0..n).map(|i| sampler.probability(&h, i)).collect();
        // Pearson correlation between p and q should be high.
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let (mp, mq) = (mean(&p), mean(&q));
        let cov: f64 =
            p.iter().zip(&q).map(|(a, b)| (a - mp) * (b - mq)).sum();
        let vp: f64 = p.iter().map(|a| (a - mp) * (a - mp)).sum();
        let vq: f64 = q.iter().map(|b| (b - mq) * (b - mq)).sum();
        let corr = cov / (vp.sqrt() * vq.sqrt());
        assert!(corr > 0.9, "correlation q↔p = {corr}");
    }

    #[test]
    fn quadratic_sampler_matches_kernel_exactly() {
        let mut rng = Rng::seeded(102);
        let n = 32;
        let d = 8;
        let classes = normalized_classes(&mut rng, n, d);
        let sampler = QuadraticSampler::new(&classes, 100.0, 1.0);
        let h = unit_vector(&mut rng, d);
        // Brute-force kernel distribution.
        let k: Vec<f64> = (0..n)
            .map(|i| {
                let s = crate::linalg::dot(&h, classes.row(i)) as f64;
                100.0 * s * s + 1.0
            })
            .collect();
        let tot: f64 = k.iter().sum();
        for i in 0..n {
            let q = sampler.probability(&h, i);
            let want = k[i] / tot;
            assert!(
                (q - want).abs() < 1e-4,
                "class {i}: q {q} vs kernel {want}"
            );
        }
    }

    #[test]
    fn update_class_shifts_distribution() {
        let mut rng = Rng::seeded(103);
        let n = 16;
        let d = 8;
        let classes = normalized_classes(&mut rng, n, d);
        let mut sampler = QuadraticSampler::new(&classes, 100.0, 1.0);
        let h = unit_vector(&mut rng, d);
        let before = sampler.probability(&h, 3);
        // Move class 3 onto h ⇒ its kernel value (and q) must rise.
        sampler.update_class(3, &h);
        let after = sampler.probability(&h, 3);
        assert!(
            after > before,
            "q(3) should increase after aligning: {before} → {after}"
        );
    }

    #[test]
    fn update_matches_rebuild() {
        let mut rng = Rng::seeded(104);
        let n = 24;
        let d = 6;
        let classes = normalized_classes(&mut rng, n, d);
        let mut a =
            RffSampler::new(&classes, 64, 1.0, &mut Rng::seeded(500));
        // Apply updates then compare against a freshly-built sampler with
        // identical map (same seed) and final embeddings.
        let mut final_classes = classes.clone();
        for step in 0..10 {
            let i = step % n;
            let e = unit_vector(&mut rng, d);
            a.update_class(i, &e);
            final_classes.row_mut(i).copy_from_slice(&e);
        }
        let b = RffSampler::new(&final_classes, 64, 1.0, &mut Rng::seeded(500));
        let h = unit_vector(&mut rng, d);
        for i in 0..n {
            let pa = a.probability(&h, i);
            let pb = b.probability(&h, i);
            assert!(
                (pa - pb).abs() < 1e-4 * pa.max(pb).max(1e-9),
                "class {i}: {pa} vs {pb}"
            );
        }
    }

    #[test]
    fn sample_negatives_excludes_target() {
        let mut rng = Rng::seeded(105);
        let n = 20;
        let d = 4;
        let classes = normalized_classes(&mut rng, n, d);
        let sampler = RffSampler::new(&classes, 32, 2.0, &mut rng);
        let h = unit_vector(&mut rng, d);
        let draw = sampler.sample_negatives(&h, 7, 50, &mut rng);
        assert_eq!(draw.len(), 50);
        assert!(draw.ids.iter().all(|&i| i != 7));
        assert!(draw.probs.iter().all(|&q| q > 0.0 && q <= 1.0));
    }

    #[test]
    fn sample_batch_preserves_exact_per_example_probabilities() {
        let mut rng = Rng::seeded(107);
        let n = 30;
        let d = 6;
        let classes = normalized_classes(&mut rng, n, d);
        let sampler = RffSampler::new(&classes, 64, 2.0, &mut rng);
        let bsz = 8;
        let mut h = Matrix::zeros(bsz, d);
        for b in 0..bsz {
            let v = unit_vector(&mut rng, d);
            h.row_mut(b).copy_from_slice(&v);
        }
        let targets: Vec<u32> = (0..bsz as u32).collect();
        // bsz·m ≥ 64 ⇒ exercises the parallel fan-out when cores allow.
        let batch = sampler.sample_batch(&h, &targets, 40, &mut rng);
        assert_eq!(batch.batch(), bsz);
        for (b, draw) in batch.draws.iter().enumerate() {
            assert_eq!(draw.len(), 40);
            let t = targets[b] as usize;
            let q_t = sampler.probability(h.row(b), t);
            for (&id, &q) in draw.ids.iter().zip(&draw.probs) {
                assert_ne!(id as usize, t);
                let want =
                    sampler.probability(h.row(b), id as usize) / (1.0 - q_t);
                assert!(
                    (q - want).abs() < 1e-9 * want.max(1e-12),
                    "example {b} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn batched_update_classes_matches_serial() {
        let mut rng = Rng::seeded(108);
        let n = 20;
        let d = 6;
        let classes = normalized_classes(&mut rng, n, d);
        let mut a = RffSampler::new(&classes, 32, 1.5, &mut Rng::seeded(600));
        let mut b = RffSampler::new(&classes, 32, 1.5, &mut Rng::seeded(600));
        let ids: Vec<u32> = vec![1, 4, 9, 16];
        let mut emb = Matrix::zeros(ids.len(), d);
        for r in 0..ids.len() {
            let e = unit_vector(&mut rng, d);
            emb.row_mut(r).copy_from_slice(&e);
        }
        a.update_classes(&ids, &emb);
        for (r, &c) in ids.iter().enumerate() {
            b.update_class(c as usize, emb.row(r));
        }
        let h = unit_vector(&mut rng, d);
        for i in 0..n {
            let pa = a.probability(&h, i);
            let pb = b.probability(&h, i);
            assert!(
                (pa - pb).abs() < 1e-7 * pa.max(pb).max(1e-9),
                "class {i}: {pa} vs {pb}"
            );
        }
    }

    #[test]
    fn fork_of_unsharded_rff_preserves_distribution() {
        let mut rng = Rng::seeded(109);
        let classes = normalized_classes(&mut rng, 30, 8);
        let mut sampler = RffSampler::new(&classes, 64, 2.0, &mut rng);
        let mut fork = sampler.fork().expect("rff sampler must fork");
        assert_eq!(fork.name(), "rff");
        let h = unit_vector(&mut rng, 8);
        for i in 0..30 {
            let a = sampler.probability(&h, i);
            let b = fork.probability(&h, i);
            assert!(
                (a - b).abs() < 1e-12 * a.max(b).max(1e-12),
                "class {i}: {a} vs {b}"
            );
        }
        // The fork keeps tracking updates exactly like the original.
        let e = unit_vector(&mut rng, 8);
        sampler.update_class(4, &e);
        fork.update_class(4, &e);
        for i in 0..30 {
            let a = sampler.probability(&h, i);
            let b = fork.probability(&h, i);
            assert!(
                (a - b).abs() < 1e-9 * a.max(b).max(1e-12),
                "post-update class {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn top_k_matches_probability_ranking() {
        let mut rng = Rng::seeded(110);
        let classes = normalized_classes(&mut rng, 40, 6);
        let sampler = RffSampler::new(&classes, 64, 2.0, &mut rng);
        let h = unit_vector(&mut rng, 6);
        let got = sampler.top_k(&h, 6);
        let mut brute: Vec<(u32, f64)> = (0..40)
            .map(|i| (i as u32, sampler.probability(&h, i)))
            .collect();
        brute.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        assert_eq!(got.len(), 6);
        for (j, ((gi, gq), (bi, bq))) in got.iter().zip(&brute).enumerate() {
            assert!(
                (gq - bq).abs() < 1e-12 * bq.max(1e-12),
                "rank {j}: q {gq} vs {bq}"
            );
            assert!(
                gi == bi || (gq - bq).abs() < 1e-15,
                "rank {j}: id {gi} vs {bi}"
            );
        }
    }

    #[test]
    fn unsharded_churn_matches_scratch_rebuild_and_forks_with_holes() {
        // Quadratic kernel: strictly positive masses, so probabilities
        // are pad-layout-independent and a from-scratch rebuild on the
        // live set is an exact reference (up to ε/fp).
        let mut rng = Rng::seeded(150);
        let d = 6;
        let classes = normalized_classes(&mut rng, 10, d);
        let mut s = QuadraticSampler::new(&classes, 100.0, 1.0);
        let mut all = classes.clone();
        // Add 12 classes (forces a pad doubling from 16 → 32), retire 4.
        let mut add = Matrix::zeros(12, d);
        for r in 0..12 {
            let v = unit_vector(&mut rng, d);
            add.row_mut(r).copy_from_slice(&v);
            all.push_row(add.row(r));
        }
        let ids = s.add_classes(&add).unwrap();
        assert_eq!(ids, (10u32..22).collect::<Vec<_>>());
        s.retire_classes(&[2, 9, 13, 21]).unwrap();
        assert_eq!(s.num_classes(), 22);
        assert_eq!(s.live_classes(), 18);
        // Mutation errors are typed, not panics.
        assert!(s.retire_classes(&[2]).is_err(), "double retire");
        assert!(s.retire_classes(&[99]).is_err(), "out of range");

        let live_ids: Vec<usize> = (0..22)
            .filter(|i| ![2usize, 9, 13, 21].contains(i))
            .collect();
        let mut live_mat = Matrix::zeros(0, d);
        for &g in &live_ids {
            live_mat.push_row(all.row(g));
        }
        let reference = QuadraticSampler::new(&live_mat, 100.0, 1.0);
        let h = unit_vector(&mut rng, d);
        let mut total = 0.0;
        for (rank, &g) in live_ids.iter().enumerate() {
            let a = s.probability(&h, g);
            let b = reference.probability(&h, rank);
            assert!(
                (a - b).abs() < 1e-3 * a.max(b).max(1e-7),
                "global {g} / rank {rank}: churned {a} vs rebuilt {b}"
            );
            total += a;
        }
        for &r in &[2usize, 9, 13, 21] {
            assert_eq!(s.probability(&h, r), 0.0, "retired class {r}");
        }
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
        // Draws and negatives never emit holes.
        let draw = s.sample(&h, 10_000, &mut rng);
        assert!(draw.ids.iter().all(|&i| !matches!(i, 2 | 9 | 13 | 21)));
        let negs = s.sample_negatives(&h, 0, 2000, &mut rng);
        assert!(negs
            .ids
            .iter()
            .all(|&i| !matches!(i, 0 | 2 | 9 | 13 | 21)));
        // The serving fork reproduces the holes exactly.
        let fork = s.fork().expect("kernel sampler must fork");
        assert_eq!(fork.num_classes(), 22);
        assert_eq!(fork.live_classes(), 18);
        for i in 0..22 {
            let a = s.probability(&h, i);
            let b = fork.probability(&h, i);
            assert!(
                (a - b).abs() < 1e-6 * a.max(b).max(1e-9),
                "fork class {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn quantized_store_tracks_f32_and_survives_updates() {
        let mut rng = Rng::seeded(120);
        let n = 32;
        let d = 8;
        let classes = normalized_classes(&mut rng, n, d);
        let exact = QuadraticSampler::new(&classes, 100.0, 1.0);
        let h = unit_vector(&mut rng, d);
        for (kind, tol) in
            [(QuantizeKind::F16, 2e-3), (QuantizeKind::I8, 5e-2)]
        {
            let mut q =
                QuadraticSampler::new_opts(&classes, 100.0, 1.0, 0, kind);
            assert_eq!(q.quantize(), kind);
            assert!(
                q.memory_bytes() < exact.memory_bytes(),
                "{kind:?} must shrink the class copy"
            );
            let mut total = 0.0;
            for i in 0..n {
                let a = exact.probability(&h, i);
                let b = q.probability(&h, i);
                assert!(
                    (a - b).abs() < tol * a.max(1e-6),
                    "{kind:?} class {i}: {a} vs {b}"
                );
                total += b;
            }
            assert!((total - 1.0).abs() < 1e-6, "{kind:?}: Σq = {total}");
            // Incremental updates must keep the tree in sync with the
            // quantized store: after rewriting every row, the churned
            // sampler matches one built fresh from the final embeddings.
            let mut finals = classes.clone();
            for i in 0..n {
                let e = unit_vector(&mut rng, d);
                q.update_class(i, &e);
                finals.row_mut(i).copy_from_slice(&e);
            }
            let fresh =
                QuadraticSampler::new_opts(&finals, 100.0, 1.0, 0, kind);
            for i in 0..n {
                let a = q.probability(&h, i);
                let b = fresh.probability(&h, i);
                assert!(
                    (a - b).abs() < 1e-4 * a.max(b).max(1e-9),
                    "{kind:?} post-update class {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn sorf_variant_works_end_to_end() {
        let mut rng = Rng::seeded(106);
        let classes = normalized_classes(&mut rng, 10, 8);
        let sampler = RffSampler::with_kind(
            &classes,
            32,
            2.0,
            FeatureMapKind::Sorf,
            &mut rng,
        );
        let h = unit_vector(&mut rng, 8);
        let draw = sampler.sample(&h, 16, &mut rng);
        assert_eq!(draw.len(), 16);
        let total: f64 = (0..10).map(|i| sampler.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6);
    }
}
