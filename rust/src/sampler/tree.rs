//! The divide-and-conquer kernel sampling tree (paper §3.1; Blanc &
//! Rendle 2018).
//!
//! Classes live at the leaves of an implicit complete binary tree; each
//! internal node stores the *sum of feature vectors* `S = Σ φ(c_j)` over
//! its **left** subtree (the right subtree's sum is recovered as
//! `parent − left`, halving memory). Given a query `z = φ(h)`:
//!
//! * the mass of a subtree is `zᵀS` — one `O(D)` dot per level,
//! * sampling walks root→leaf choosing branches proportionally to their
//!   masses: `O(D log n)` per draw,
//! * updating one class adds `Δ = φ_new − φ_old` along its root→leaf path:
//!   `O(D log n)` per update,
//! * the probability of the reached leaf is the telescoping product of
//!   branch probabilities — with all-positive leaf masses it equals
//!   `zᵀφ(c_i) / zᵀΣ_j φ(c_j)` exactly.
//!
//! **Negativity handling** (an implementation reality the paper inherits
//! from [12] without discussion): RFF inner products can be negative.
//! Branch masses are clamped at 0 and every *real* leaf carries a small
//! `ε` floor, so `q_i > 0` for all classes (required by Theorem 1) and the
//! returned probability is always the exact probability of the walk that
//! produced the sample — the estimator stays unbiased regardless of the
//! clamping.
//!
//! Memory is `O(n·D)` floats (`pad−1` left-sums + the root total), the
//! inherent cost of the data structure.
//!
//! **Mutable class universe**: the tree supports runtime growth and
//! shrinkage. [`KernelTree::insert_class`] appends a leaf, doubling the
//! padded capacity when full (the old tree becomes the left subtree of a
//! fresh root — an `O(n·D)` copy amortized to `O(D)` per insert, so an
//! insert is amortized `O(D log n)` including the path update).
//! [`KernelTree::retire_class`] subtracts the leaf's φ and drops it from
//! the per-subtree **live-leaf counts** that drive the ε floor, so a
//! retired slot carries exactly zero effective mass — the walk can never
//! end there, its ε floor vanishes, and `probability` returns an exact 0.
//! Retired slots are holes: ids stay stable and are never reused.
//! [`KernelTree::with_capacity`] pre-pads to a planned capacity so a
//! known growth schedule never pays the doubling copies.
//!
//! **Cache behavior**: the interior sums live in heap order, which puts
//! the top levels in one compact block at the front of `left_sums` —
//! they stay cache-resident across consecutive draws (the batched walks
//! in `sample_many`/`serve_queries` lean on exactly this, plus an eager
//! sequential sweep of the memo cache's top block). Deeper levels are
//! sparse and DRAM-bound; the walk software-prefetches both children
//! one level ahead so the line fetch overlaps the current node's dot.

use crate::linalg::{dot, simd};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct KernelTree {
    /// Feature dimension D (the map's *output* dim).
    dim: usize,
    /// Number of leaf slots ever created (live + retired; phantom
    /// padding excluded). Slot ids are stable: `0..n`, holes allowed.
    n: usize,
    /// Leaves padded to a power of two; phantom leaves hold φ = 0.
    pad: usize,
    /// Left-child subtree sums for internal nodes 1..pad-1 (heap order),
    /// flattened: node k's sum at `[(k-1)*dim .. k*dim]`.
    left_sums: Vec<f32>,
    /// Live-leaf count of each internal node's **left** subtree (heap
    /// order, parallel to `left_sums`). Drives the ε floor and keeps
    /// retired/phantom subtrees at exactly zero effective mass.
    left_live: Vec<u32>,
    /// Sum over all leaves (the root's total).
    total: Vec<f32>,
    /// Total live (non-retired) leaves.
    live: usize,
    /// Per-slot retirement flags (`retired[i]` ⇒ slot i is a hole).
    retired: Vec<bool>,
    /// Per-leaf probability floor (pseudo-mass added to every live leaf).
    eps: f64,
    /// Capacity-doubling copies performed since construction (telemetry
    /// for the pre-reservation path: stays 0 when `with_capacity`
    /// covered the whole growth schedule).
    growths: usize,
}

impl KernelTree {
    /// Empty tree for `n` classes with feature dim `dim`.
    pub fn new(n: usize, dim: usize, eps: f64) -> Self {
        Self::with_capacity(n, dim, eps, 0)
    }

    /// Empty tree for `n` classes whose padding is pre-reserved for
    /// `capacity` total slots (`sampler.max_capacity`): a known growth
    /// schedule then never pays a capacity-doubling copy —
    /// [`KernelTree::growths`] stays 0. `capacity ≤ n` (including 0)
    /// reserves nothing and is identical to [`KernelTree::new`].
    pub fn with_capacity(n: usize, dim: usize, eps: f64, capacity: usize) -> Self {
        assert!(n >= 1, "KernelTree: need at least one class");
        assert!(dim >= 1);
        assert!(eps > 0.0, "KernelTree: eps must be > 0 (Theorem 1 needs q_i > 0)");
        // Padding invariant: `pad = next_pow2(max(n, capacity)).max(2)`.
        // The `.max(2)` is load-bearing for n = 1 — without it `pad = 1`,
        // `left_sums` is empty, and the very first walk iteration would
        // index node 1 out of bounds. With pad = 2 a single-class tree
        // has one internal node whose right (phantom) child carries zero
        // mass, so the walk deterministically ends at leaf 0 with q = 1.
        // This is exactly the degenerate shape
        // [`super::ShardedKernelTree`] produces for its single-class tail
        // shards.
        let pad = n.max(capacity).next_power_of_two().max(2);
        debug_assert!(
            pad >= 2 && pad.is_power_of_two() && pad >= n,
            "KernelTree: pad invariant violated (n={n}, pad={pad})"
        );
        let mut t = Self {
            dim,
            n,
            pad,
            left_sums: vec![0.0; (pad - 1) * dim],
            left_live: vec![0; pad - 1],
            total: vec![0.0; dim],
            live: n,
            retired: vec![false; n],
            eps,
            growths: 0,
        };
        t.init_left_live();
        t
    }

    /// Recompute every internal node's left-subtree live count from the
    /// contiguous all-live layout `0..n` (construction and growth; later
    /// mutations maintain the counts incrementally).
    fn init_left_live(&mut self) {
        let mut depth_start = 1usize; // first heap index at this depth
        let mut size = self.pad; // subtree size at this depth
        while size > 1 {
            let half = size / 2;
            for k in depth_start..depth_start * 2 {
                let lo = (k - depth_start) * size;
                self.left_live[k - 1] =
                    self.n.saturating_sub(lo).min(half) as u32;
            }
            depth_start *= 2;
            size = half;
        }
    }

    /// Build from per-class feature vectors (φ(c_0), …, φ(c_{n-1})).
    pub fn build<'a>(
        n: usize,
        dim: usize,
        eps: f64,
        mut phi_of: impl FnMut(usize) -> &'a [f32],
    ) -> Self {
        let mut t = Self::new(n, dim, eps);
        for i in 0..n {
            let phi = phi_of(i);
            t.add_leaf(i, phi);
        }
        t
    }

    pub fn num_classes(&self) -> usize {
        self.n
    }

    /// Live (non-retired) classes — the support of the distribution.
    pub fn live_classes(&self) -> usize {
        self.live
    }

    /// Whether slot `i` has been retired (a permanent hole).
    pub fn is_retired(&self, i: usize) -> bool {
        self.retired[i]
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn eps(&self) -> f64 {
        self.eps
    }

    /// Memory footprint of the node sums + live counts, in bytes.
    pub fn memory_bytes(&self) -> usize {
        (self.left_sums.len() + self.total.len()) * std::mem::size_of::<f32>()
            + self.left_live.len() * std::mem::size_of::<u32>()
    }

    /// Predicted [`KernelTree::memory_bytes`] for an `(n, dim)` tree that
    /// has not been built yet, derived from the tree's actual storage
    /// elements (`pad − 1` left-sums plus the root total, each `dim`
    /// floats, plus `pad − 1` live counts). `build_sampler`'s memory
    /// fallback uses this so its threshold cannot drift from the real
    /// storage type; pass the planned **capacity** (`sampler.
    /// max_capacity`), not just the current class count, when the
    /// universe is expected to grow — capacity doubling means a tree that
    /// outgrew its seed size occupies `next_pow2(slots)`, exactly what
    /// this predicts for `n = slots`.
    pub fn estimate_bytes(n: usize, dim: usize) -> usize {
        let pad = n.next_power_of_two().max(2);
        pad * dim * std::mem::size_of::<f32>()
            + (pad - 1) * std::mem::size_of::<u32>()
    }

    /// Double the padded capacity: the existing tree becomes the **left
    /// subtree** of a fresh root, so every stored sum and live count is
    /// moved (not recomputed) — old heap node `k` at depth ℓ maps to
    /// `k + 2^ℓ`, the new root's left sum is the old total, and the new
    /// right half is all-phantom. `O(pad · D)` copy, amortized `O(D)`
    /// per insert across the `pad/2` inserts that fit before the next
    /// doubling. Preserves the `pad = next_pow2(n).max(2)` invariant.
    fn grow(&mut self) {
        let old_pad = self.pad;
        let new_pad = old_pad * 2;
        let dim = self.dim;
        let mut sums = vec![0.0f32; (new_pad - 1) * dim];
        let mut lives = vec![0u32; new_pad - 1];
        sums[..dim].copy_from_slice(&self.total);
        lives[0] = self.live as u32;
        for k in 1..old_pad {
            // floor(log2 k) without fp: position of k's leading bit.
            let msb = 1usize << (usize::BITS - 1 - k.leading_zeros());
            let nk = k + msb;
            sums[(nk - 1) * dim..nk * dim]
                .copy_from_slice(&self.left_sums[(k - 1) * dim..k * dim]);
            lives[nk - 1] = self.left_live[k - 1];
        }
        self.left_sums = sums;
        self.left_live = lives;
        self.pad = new_pad;
        self.growths += 1;
        debug_assert_eq!(self.pad, self.n.next_power_of_two().max(2) * 2);
    }

    /// How many capacity-doubling copies this tree has paid since
    /// construction. A tree whose `with_capacity` reservation covered
    /// every insert reports 0 — the pre-reservation churn test asserts
    /// exactly that.
    pub fn growths(&self) -> usize {
        self.growths
    }

    /// Append a new class with feature vector `phi`, returning its slot
    /// id (`== num_classes()` before the call; ids are stable forever).
    /// Amortized `O(D log n)`: one root→leaf sum update plus the
    /// capacity-doubling copy amortized over the inserts that fit in it.
    pub fn insert_class(&mut self, phi: &[f32]) -> usize {
        assert_eq!(phi.len(), self.dim, "insert_class: dim mismatch");
        if self.n == self.pad {
            self.grow();
        }
        let i = self.n;
        self.n += 1;
        self.retired.push(false);
        self.live += 1;
        self.adjust_live(i, 1);
        self.update_leaf(i, phi);
        debug_assert!(
            self.pad.is_power_of_two() && self.pad >= self.n.max(2),
            "insert_class: pad invariant violated (n={}, pad={})",
            self.n,
            self.pad
        );
        i
    }

    /// Retire slot `i`: subtract its current feature vector `phi` (the
    /// caller owns φ — the tree stores only sums) and remove it from the
    /// live counts, so the slot's effective mass is exactly zero: never
    /// sampled, never in `top_k`, `probability` returns an exact 0, no ε
    /// floor. `O(D log n)`. The slot id stays valid (a hole) and is
    /// never reused.
    pub fn retire_class(&mut self, i: usize, phi: &[f32]) {
        assert!(i < self.n, "retire_class: class {i} out of range");
        assert!(!self.retired[i], "retire_class: class {i} already retired");
        // live may legitimately drain to 0 here: a ShardedKernelTree
        // shard with no survivors simply carries zero weight. Samplers
        // that serve a distribution enforce "≥ 1 live" at their layer.
        assert_eq!(phi.len(), self.dim, "retire_class: dim mismatch");
        let neg: Vec<f32> = phi.iter().map(|x| -x).collect();
        self.update_leaf(i, &neg);
        self.retired[i] = true;
        self.live -= 1;
        self.adjust_live(i, -1);
    }

    /// Un-retire slot `i`, re-seeding it with `phi` — for **container**
    /// leaves only (e.g. [`crate::sampler::BucketKernelSampler`]'s
    /// bucket-level tree, where a drained tail bucket refills when new
    /// classes append into it). Class-level samplers never revive: class
    /// ids stay permanent holes. `O(D log n)`.
    pub fn revive_class(&mut self, i: usize, phi: &[f32]) {
        assert!(i < self.n, "revive_class: slot {i} out of range");
        assert!(self.retired[i], "revive_class: slot {i} is not retired");
        self.retired[i] = false;
        self.live += 1;
        self.adjust_live(i, 1);
        self.update_leaf(i, phi);
    }

    /// Add `delta` to the live count along leaf `i`'s root→leaf path
    /// (left-descents only — right-subtree counts are derived).
    fn adjust_live(&mut self, i: usize, delta: i32) {
        let mut node = 1usize;
        let mut lo = 0usize;
        let mut size = self.pad;
        while size > 1 {
            let half = size / 2;
            if i < lo + half {
                let c = &mut self.left_live[node - 1];
                *c = c.wrapping_add_signed(delta);
                node *= 2;
            } else {
                node = node * 2 + 1;
                lo += half;
            }
            size = half;
        }
    }

    /// Uniform draw over **live** leaves, optionally excluding one live
    /// `target` — the never-aborting fallback for
    /// [`KernelTree::sample_negatives`] in a universe with holes (a flat
    /// `uniform_excluding(n, …)` would emit retired slots). Walks the
    /// live counts root→leaf: `O(log n)`, exact `1/(live − |excl|)` per
    /// candidate.
    pub fn uniform_live_excluding(
        &self,
        target: Option<usize>,
        rng: &mut Rng,
    ) -> usize {
        if let Some(t) = target {
            debug_assert!(t < self.n && !self.retired[t]);
        }
        let in_range = |t: Option<usize>, lo: usize, size: usize| -> usize {
            match t {
                Some(t) if t >= lo && t < lo + size => 1,
                _ => 0,
            }
        };
        let avail = self.live - target.map_or(0, |_| 1);
        assert!(avail >= 1, "uniform_live_excluding: no live candidates");
        let mut node = 1usize;
        let mut lo = 0usize;
        let mut size = self.pad;
        let mut live = self.live; // raw live count of current subtree
        while size > 1 {
            let half = size / 2;
            let nl_raw = self.left_live[node - 1] as usize;
            let nr_raw = live - nl_raw;
            let nl = nl_raw - in_range(target, lo, half);
            let nr = nr_raw - in_range(target, lo + half, half);
            debug_assert!(nl + nr > 0, "no candidates under node {node}");
            if rng.below((nl + nr) as u64) < nl as u64 {
                live = nl_raw;
                node *= 2;
            } else {
                live = nr_raw;
                node = node * 2 + 1;
                lo += half;
            }
            size = half;
        }
        debug_assert!(
            lo < self.n && !self.retired[lo] && target != Some(lo),
            "uniform_live_excluding landed on slot {lo}"
        );
        lo
    }

    /// Same `(n, dim, pad)` shape as `other` (copyable in place).
    pub fn same_shape(&self, other: &KernelTree) -> bool {
        self.n == other.n && self.dim == other.dim && self.pad == other.pad
    }

    /// Copy another tree's node sums into this one without reallocating —
    /// in-place state restoration for callers managing their own spare
    /// tree allocations (external double-buffer or checkpoint-restore
    /// schemes; the in-crate serving writer instead recycles whole
    /// snapshots via `Arc::try_unwrap` + replay). Shapes must match
    /// (see [`KernelTree::same_shape`]).
    pub fn copy_state_from(&mut self, src: &KernelTree) {
        assert!(
            self.same_shape(src),
            "copy_state_from: shape mismatch (n {} vs {}, dim {} vs {})",
            self.n,
            src.n,
            self.dim,
            src.dim
        );
        self.left_sums.copy_from_slice(&src.left_sums);
        self.left_live.copy_from_slice(&src.left_live);
        self.total.copy_from_slice(&src.total);
        self.live = src.live;
        self.retired.clear();
        self.retired.extend_from_slice(&src.retired);
        self.eps = src.eps;
    }

    /// Capture the tree's full state as plain data for the durable
    /// snapshot codec ([`crate::snapshot`]). Exact: node sums are the
    /// stored f32s bit for bit, so a restored tree walks identically.
    pub fn to_state(&self) -> crate::snapshot::TreeState {
        crate::snapshot::TreeState {
            dim: self.dim,
            n: self.n,
            pad: self.pad,
            left_sums: self.left_sums.clone(),
            left_live: self.left_live.clone(),
            total: self.total.clone(),
            live: self.live,
            retired: self.retired.clone(),
            eps: self.eps,
            growths: self.growths,
        }
    }

    /// Rebuild a tree from captured state. `O(state size)` — no φ
    /// recomputation, which is the whole point of warm restore. The
    /// state is re-validated here even though the codec validates on
    /// decode, so in-process callers (restore over RPC, tests) get the
    /// same typed failure instead of a corrupt tree.
    pub fn from_state(
        s: &crate::snapshot::TreeState,
    ) -> Result<KernelTree, crate::snapshot::SnapshotError> {
        s.validate()?;
        Ok(KernelTree {
            dim: s.dim,
            n: s.n,
            pad: s.pad,
            left_sums: s.left_sums.clone(),
            left_live: s.left_live.clone(),
            total: s.total.clone(),
            live: s.live,
            retired: s.retired.clone(),
            eps: s.eps,
            growths: s.growths,
        })
    }

    #[inline]
    fn left_sum(&self, node: usize) -> &[f32] {
        &self.left_sums[(node - 1) * self.dim..node * self.dim]
    }

    #[inline]
    fn left_sum_mut(&mut self, node: usize) -> &mut [f32] {
        &mut self.left_sums[(node - 1) * self.dim..node * self.dim]
    }

    /// Software-prefetch both children's left-sum rows one level ahead
    /// of the walk: while the current node's `O(D)` dot executes, the
    /// lines the *next* branch decision needs are already in flight.
    /// The heap layout keeps the top levels contiguous at the front of
    /// `left_sums` (cache-resident across consecutive draws); prefetch
    /// mostly pays off in the deep, sparse levels. `2·node < pad`
    /// guards both children: `pad` is even, so an even `2·node ≤ pad−1`
    /// implies `2·node + 1 ≤ pad − 1` as well.
    #[inline]
    fn prefetch_children(&self, node: usize) {
        let l = 2 * node;
        if l < self.pad {
            simd::prefetch_read(self.left_sum(l));
            simd::prefetch_read(self.left_sum(l + 1));
        }
    }

    /// Add `delta` to class `i`'s leaf (and all ancestor sums).
    pub fn update_leaf(&mut self, i: usize, delta: &[f32]) {
        assert!(i < self.n, "update_leaf: class {i} out of range");
        assert_eq!(delta.len(), self.dim);
        // retire_class flips the flag only after its own subtraction, so
        // this rejects exactly the external writes a hole must never see.
        assert!(!self.retired[i], "update_leaf: class {i} is retired");
        for (t, d) in self.total.iter_mut().zip(delta.iter()) {
            *t += d;
        }
        // Walk root→leaf; when we descend left, the node's left-sum
        // includes this leaf.
        let mut node = 1usize;
        let mut lo = 0usize;
        let mut size = self.pad;
        while size > 1 {
            let half = size / 2;
            if i < lo + half {
                let ls = self.left_sum_mut(node);
                for (t, d) in ls.iter_mut().zip(delta.iter()) {
                    *t += d;
                }
                node *= 2;
            } else {
                node = node * 2 + 1;
                lo += half;
            }
            size = half;
        }
    }

    /// Initialize class `i`'s leaf value (identical to `update_leaf`, kept
    /// separate for call-site clarity during construction).
    pub fn add_leaf(&mut self, i: usize, phi: &[f32]) {
        self.update_leaf(i, phi);
    }

    /// Total (unclamped) kernel mass `zᵀ Σ_j φ(c_j)` for a query.
    pub fn mass(&self, z: &[f32]) -> f64 {
        dot(&self.total, z) as f64
    }

    /// Effective root mass for a query: the normalizer every leaf's
    /// `q_i(z)` is divided by (clamped + ε·live, zero when nothing is
    /// live). This is the sampler's advertised mass in a cluster —
    /// `q_i(z) · effective_mass(z)` is leaf `i`'s absolute mass, which
    /// merges exactly across replicas holding disjoint class shards.
    pub fn effective_mass(&self, z: &[f32]) -> f64 {
        self.eff(self.mass(z), self.live)
    }

    /// Effective (clamped + ε·count) mass of a subtree, given its raw
    /// mass and **live**-leaf count.
    ///
    /// A subtree with no live leaves (all phantom padding, all retired,
    /// or both) has *exactly* zero mass by construction; its raw value
    /// reaches us via a chain of f32 subtractions whose rounding error
    /// would otherwise leak real probability into dead leaves (observed
    /// ~1% at n≈40 when most masses clamp to the ε floor), so it is
    /// forced to 0 here — this is also what guarantees a retired slot is
    /// never emitted.
    #[inline]
    fn eff(&self, raw: f64, live_leaves: usize) -> f64 {
        if live_leaves == 0 {
            return 0.0;
        }
        raw.max(0.0) + self.eps * live_leaves as f64
    }

    /// Draw one class: returns `(class, q)` where `q` is the exact
    /// probability of this draw under the clamped walk. `O(D log n)`.
    pub fn sample(&self, z: &[f32], rng: &mut Rng) -> (usize, f64) {
        debug_assert_eq!(z.len(), self.dim);
        let mut node = 1usize;
        let mut lo = 0usize;
        let mut size = self.pad;
        let mut raw = self.mass(z);
        let mut live = self.live;
        let mut q = 1.0f64;
        while size > 1 {
            self.prefetch_children(node);
            let half = size / 2;
            let raw_left = dot(self.left_sum(node), z) as f64;
            let raw_right = raw - raw_left;
            let nl = self.left_live[node - 1] as usize;
            let nr = live - nl;
            let el = self.eff(raw_left, nl);
            let er = self.eff(raw_right, nr);
            let tot = el + er;
            debug_assert!(tot > 0.0, "zero effective mass at node {node}");
            let p_left = el / tot;
            if rng.f64() < p_left {
                q *= p_left;
                raw = raw_left;
                live = nl;
                node *= 2;
            } else {
                q *= 1.0 - p_left;
                raw = raw_right;
                live = nr;
                node = node * 2 + 1;
                lo += half;
            }
            size = half;
        }
        debug_assert!(
            lo < self.n && !self.retired[lo],
            "sampled dead leaf {lo}"
        );
        (lo, q)
    }

    /// Exact probability that [`sample`] returns class `i` for query `z`.
    /// `O(D log n)`. An exact `0.0` for retired slots (their subtree's
    /// effective mass is forced to zero at the last branch).
    pub fn probability(&self, z: &[f32], i: usize) -> f64 {
        assert!(i < self.n);
        let mut node = 1usize;
        let mut lo = 0usize;
        let mut size = self.pad;
        let mut raw = self.mass(z);
        let mut live = self.live;
        let mut q = 1.0f64;
        while size > 1 {
            self.prefetch_children(node);
            let half = size / 2;
            let raw_left = dot(self.left_sum(node), z) as f64;
            let raw_right = raw - raw_left;
            let nl = self.left_live[node - 1] as usize;
            let nr = live - nl;
            let el = self.eff(raw_left, nl);
            let er = self.eff(raw_right, nr);
            let tot = el + er;
            if tot <= 0.0 {
                return 0.0; // dead subtree: exact zero, no 0/0
            }
            let p_left = el / tot;
            if i < lo + half {
                q *= p_left;
                raw = raw_left;
                live = nl;
                node *= 2;
            } else {
                q *= 1.0 - p_left;
                raw = raw_right;
                live = nr;
                node = node * 2 + 1;
                lo += half;
            }
            size = half;
        }
        q
    }

    /// Draw `m` classes i.i.d. for one shared query.
    ///
    /// Perf (§Perf iteration 1): the m walks share the upper levels of the
    /// tree, so the `zᵀS_left` dot products there are memoized in a flat
    /// per-call cache (top `MEMO_NODES` heap slots; O(1) lookup, no
    /// hashing). For m = 100 at n = 10k this removes ~40% of the dot
    /// products versus m independent [`KernelTree::sample`] calls — see
    /// `benches/perf_hotpath.rs` (`rff_draw` vs `rff_draw_nomemo`).
    pub fn sample_many(
        &self,
        z: &[f32],
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        const MEMO_NODES: usize = 4096;
        let cache_len = self.pad.min(MEMO_NODES);
        let mut cache = vec![f64::NAN; cache_len];
        let root_raw = self.mass(z);
        // Eagerly fill the top of the cache in one pass: with m draws
        // the first ~log2(m) levels are visited almost surely, and heap
        // order makes this sweep stream `left_sums` sequentially
        // (hardware-prefetch friendly) instead of demand-faulting the
        // same lines mid-walk. Each entry is the identical
        // `zᵀS_left(node)` the lazy path would compute, so the draw
        // stream is byte-for-byte unchanged.
        let eager = (2 * m.next_power_of_two()).min(cache_len);
        for node in 1..eager {
            cache[node] = dot(self.left_sum(node), z) as f64;
        }

        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        for _ in 0..m {
            let mut node = 1usize;
            let mut lo = 0usize;
            let mut size = self.pad;
            let mut raw = root_raw;
            let mut live = self.live;
            let mut q = 1.0f64;
            while size > 1 {
                self.prefetch_children(node);
                let half = size / 2;
                let raw_left = if node < cache_len {
                    let c = cache[node];
                    if c.is_nan() {
                        let v = dot(self.left_sum(node), z) as f64;
                        cache[node] = v;
                        v
                    } else {
                        c
                    }
                } else {
                    dot(self.left_sum(node), z) as f64
                };
                let raw_right = raw - raw_left;
                let nl = self.left_live[node - 1] as usize;
                let nr = live - nl;
                let el = self.eff(raw_left, nl);
                let er = self.eff(raw_right, nr);
                let tot = el + er;
                debug_assert!(tot > 0.0, "zero effective mass at node {node}");
                let p_left = el / tot;
                if rng.f64() < p_left {
                    q *= p_left;
                    raw = raw_left;
                    live = nl;
                    node *= 2;
                } else {
                    q *= 1.0 - p_left;
                    raw = raw_right;
                    live = nr;
                    node = node * 2 + 1;
                    lo += half;
                }
                size = half;
            }
            debug_assert!(
                lo < self.n && !self.retired[lo],
                "sampled dead leaf {lo}"
            );
            ids.push(lo as u32);
            probs.push(q);
        }
        (ids, probs)
    }

    /// Draw `m` negatives (`≠ target`) for a pre-mapped query `z`, with
    /// probabilities renormalized by `1 − q_target` — the walk-level
    /// primitive behind the batch sampling path (the caller has already
    /// paid for `φ(h)` once; no re-mapping per draw or per probability).
    ///
    /// Uses the same memoized multi-walk as [`KernelTree::sample_many`]
    /// and the same never-aborting uniform-excluding-target fallback as
    /// [`crate::sampler::Sampler::sample_negatives`].
    pub fn sample_negatives(
        &self,
        z: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        assert!(target < self.n, "sample_negatives: target out of range");
        assert!(!self.retired[target], "sample_negatives: retired target");
        assert!(
            self.live > 1,
            "sample_negatives: need ≥ 2 live classes to exclude one"
        );
        let q_t = self.probability(z, target);
        let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);
        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        let mut rounds = 0usize;
        while ids.len() < m
            && rounds < crate::sampler::REJECTION_ROUNDS
            && q_t < crate::sampler::DEGENERATE_Q
        {
            let (cand, cand_q) = self.sample_many(z, m - ids.len(), rng);
            for (id, p) in cand.iter().zip(cand_q.iter()) {
                if *id as usize != target {
                    ids.push(*id);
                    probs.push(p / renorm);
                }
            }
            rounds += 1;
        }
        // Live-aware uniform fallback: a flat draw over `0..n` could emit
        // retired slots once the universe has holes.
        while ids.len() < m {
            ids.push(self.uniform_live_excluding(Some(target), rng) as u32);
            probs.push(1.0 / (self.live - 1) as f64);
        }
        (ids, probs)
    }

    /// The `k` leaves with the largest walk probability for query `z`,
    /// descending (ties broken by class id). Best-first branch-and-bound
    /// on partial walk products: the product of branch probabilities down
    /// to an internal node upper-bounds the probability of every leaf
    /// beneath it (all remaining factors are ≤ 1), so expanding nodes in
    /// bound order makes the first `k` leaves popped exactly the top `k`.
    /// Serves the `top_k` request type of [`crate::serving`];
    /// `O(k · D log n)` in the typical (non-adversarial) case.
    pub fn top_k(&self, z: &[f32], k: usize) -> Vec<(u32, f64)> {
        use std::cmp::Ordering as CmpOrdering;
        use std::collections::BinaryHeap;

        struct Item {
            q: f64,
            node: usize,
            lo: usize,
            size: usize,
            raw: f64,
            live: usize,
        }
        impl PartialEq for Item {
            fn eq(&self, other: &Self) -> bool {
                self.cmp(other) == CmpOrdering::Equal
            }
        }
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, other: &Self) -> Option<CmpOrdering> {
                Some(self.cmp(other))
            }
        }
        impl Ord for Item {
            fn cmp(&self, other: &Self) -> CmpOrdering {
                // Max-heap on bound; lower class range wins ties so the
                // result order is deterministic.
                self.q.total_cmp(&other.q).then(other.lo.cmp(&self.lo))
            }
        }

        let k = k.min(self.live);
        let mut out = Vec::with_capacity(k);
        if k == 0 {
            return out;
        }
        let mut heap = BinaryHeap::new();
        heap.push(Item {
            q: 1.0,
            node: 1,
            lo: 0,
            size: self.pad,
            raw: self.mass(z),
            live: self.live,
        });
        while let Some(Item { q, node, lo, size, raw, live }) = heap.pop() {
            if size == 1 {
                debug_assert!(
                    lo < self.n && !self.retired[lo],
                    "top_k reached dead leaf {lo}"
                );
                out.push((lo as u32, q));
                if out.len() == k {
                    break;
                }
                continue;
            }
            let half = size / 2;
            let raw_left = dot(self.left_sum(node), z) as f64;
            let raw_right = raw - raw_left;
            let nl = self.left_live[node - 1] as usize;
            let nr = live - nl;
            let el = self.eff(raw_left, nl);
            let er = self.eff(raw_right, nr);
            let tot = el + er;
            if tot <= 0.0 {
                continue; // dead (phantom/retired) subtree carries no mass
            }
            let p_left = el / tot;
            if el > 0.0 {
                heap.push(Item {
                    q: q * p_left,
                    node: node * 2,
                    lo,
                    size: half,
                    raw: raw_left,
                    live: nl,
                });
            }
            if er > 0.0 {
                heap.push(Item {
                    q: q * (1.0 - p_left),
                    node: node * 2 + 1,
                    lo: lo + half,
                    size: half,
                    raw: raw_right,
                    live: nr,
                });
            }
        }
        out
    }

    /// Unmemoized variant of [`KernelTree::sample_many`] (m independent
    /// walks). Kept as the §Perf baseline and for A/B testing.
    pub fn sample_many_nomemo(
        &self,
        z: &[f32],
        m: usize,
        rng: &mut Rng,
    ) -> (Vec<u32>, Vec<f64>) {
        let mut ids = Vec::with_capacity(m);
        let mut probs = Vec::with_capacity(m);
        for _ in 0..m {
            let (i, q) = self.sample(z, rng);
            ids.push(i as u32);
            probs.push(q);
        }
        (ids, probs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::propkit::{check, close, gen};

    /// Reference: exact clamped distribution computed by brute force on
    /// leaf masses (matches the tree's ε-floor semantics only when all
    /// internal partial sums are nonnegative — guaranteed for nonneg φ).
    fn brute_force_probs(phis: &[Vec<f32>], z: &[f32], eps: f64) -> Vec<f64> {
        let masses: Vec<f64> =
            phis.iter().map(|p| (dot(p, z) as f64).max(0.0) + eps).collect();
        let tot: f64 = masses.iter().sum();
        masses.iter().map(|m| m / tot).collect()
    }

    fn build_tree(phis: &[Vec<f32>], eps: f64) -> KernelTree {
        KernelTree::build(phis.len(), phis[0].len(), eps, |i| &phis[i])
    }

    #[test]
    fn probabilities_match_brute_force_for_nonneg_phi() {
        check("tree-prob-vs-brute", |rng| {
            let n = gen::usize_in(rng, 1, 40);
            let d = gen::usize_in(rng, 1, 8);
            // Nonnegative feature vectors → no clamping ambiguity.
            let phis: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.f32()).collect())
                .collect();
            let z: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
            let eps = 1e-9;
            let tree = build_tree(&phis, eps);
            let brute = brute_force_probs(&phis, &z, eps);
            for i in 0..n {
                let p = tree.probability(&z, i);
                prop_assert!(
                    close(p, brute[i], 1e-4, 1e-9),
                    "class {i}: tree {p} vs brute {}",
                    brute[i]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn probabilities_sum_to_one() {
        check("tree-prob-sums-1", |rng| {
            let n = gen::usize_in(rng, 2, 64);
            let d = gen::usize_in(rng, 1, 6);
            // Mixed-sign features exercise the clamping path.
            let phis: Vec<Vec<f32>> =
                (0..n).map(|_| gen::vector(rng, d)).collect();
            let z = gen::vector(rng, d);
            let tree = build_tree(&phis, 1e-6);
            let total: f64 = (0..n).map(|i| tree.probability(&z, i)).sum();
            prop_assert!(close(total, 1.0, 1e-6, 1e-9), "Σq = {total}");
            Ok(())
        });
    }

    #[test]
    fn sample_prob_matches_probability_query() {
        check("tree-sample-q-consistent", |rng| {
            let n = gen::usize_in(rng, 2, 50);
            let d = gen::usize_in(rng, 1, 6);
            let phis: Vec<Vec<f32>> =
                (0..n).map(|_| gen::vector(rng, d)).collect();
            let z = gen::vector(rng, d);
            let tree = build_tree(&phis, 1e-6);
            let (i, q) = tree.sample(&z, rng);
            let q2 = tree.probability(&z, i);
            prop_assert!(close(q, q2, 1e-9, 1e-15), "q {q} vs query {q2}");
            prop_assert!(i < n, "sampled phantom leaf");
            Ok(())
        });
    }

    #[test]
    fn update_equals_rebuild() {
        check("tree-update-vs-rebuild", |rng| {
            let n = gen::usize_in(rng, 2, 32);
            let d = gen::usize_in(rng, 1, 5);
            // Nonnegative φ: keeps masses away from the clamp boundary,
            // where f32 rounding makes updated-vs-rebuilt comparisons
            // ill-conditioned by construction (see `eff`).
            let mut phis: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| rng.f32()).collect())
                .collect();
            let mut tree = build_tree(&phis, 1e-6);
            // Apply a few random updates to both representations.
            for _ in 0..5 {
                let i = rng.index(n);
                let newphi: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
                let delta: Vec<f32> = newphi
                    .iter()
                    .zip(&phis[i])
                    .map(|(a, b)| a - b)
                    .collect();
                tree.update_leaf(i, &delta);
                phis[i] = newphi;
            }
            let rebuilt = build_tree(&phis, 1e-6);
            let z: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
            for i in 0..n {
                let a = tree.probability(&z, i);
                let b = rebuilt.probability(&z, i);
                prop_assert!(
                    close(a, b, 1e-3, 1e-7),
                    "class {i}: updated {a} vs rebuilt {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn empirical_frequency_matches_q() {
        let mut rng = Rng::seeded(91);
        let n = 17;
        let d = 4;
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.f32() + 0.1).collect())
            .collect();
        let z: Vec<f32> = (0..d).map(|_| rng.f32() + 0.1).collect();
        let tree = build_tree(&phis, 1e-9);
        let trials = 200_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let (i, _) = tree.sample(&z, &mut rng);
            counts[i] += 1;
        }
        for i in 0..n {
            let q = tree.probability(&z, i);
            let freq = counts[i] as f64 / trials as f64;
            let sd = (q * (1.0 - q) / trials as f64).sqrt();
            assert!(
                (freq - q).abs() < 5.0 * sd + 1e-4,
                "class {i}: freq {freq:.5} vs q {q:.5}"
            );
        }
    }

    #[test]
    fn all_negative_masses_fall_back_to_floor() {
        // Every kernel value negative → ε floor ⇒ ~uniform sampling.
        let n = 8;
        let phis: Vec<Vec<f32>> = (0..n).map(|_| vec![-1.0, -1.0]).collect();
        let tree = build_tree(&phis, 1e-6);
        let z = vec![1.0f32, 1.0];
        let mut rng = Rng::seeded(92);
        for i in 0..n {
            let q = tree.probability(&z, i);
            assert!(
                (q - 1.0 / n as f64).abs() < 1e-3,
                "class {i}: q = {q}, want ≈ 1/{n}"
            );
        }
        let (i, q) = tree.sample(&z, &mut rng);
        assert!(i < n && q > 0.0);
    }

    #[test]
    fn single_class_tree() {
        let phis = vec![vec![0.5f32, 0.5]];
        let tree = build_tree(&phis, 1e-6);
        let mut rng = Rng::seeded(93);
        let (i, q) = tree.sample(&[1.0, 1.0], &mut rng);
        assert_eq!(i, 0);
        assert!((q - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sample_many_memo_matches_nomemo_distribution() {
        // The memoized batch path must produce the same distribution as m
        // independent walks (and identical q for identical draws).
        let mut rng = Rng::seeded(95);
        let n = 33;
        let d = 5;
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.f32()).collect())
            .collect();
        let z: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        let tree = build_tree(&phis, 1e-8);
        // Same RNG stream ⇒ identical draws and probabilities.
        let (ids_a, q_a) =
            tree.sample_many(&z, 500, &mut Rng::seeded(1234));
        let (ids_b, q_b) =
            tree.sample_many_nomemo(&z, 500, &mut Rng::seeded(1234));
        assert_eq!(ids_a, ids_b);
        for (a, b) in q_a.iter().zip(&q_b) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn tree_sample_negatives_excludes_and_renormalizes() {
        let mut rng = Rng::seeded(96);
        let n = 12;
        let d = 4;
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.f32() + 0.1).collect())
            .collect();
        let z: Vec<f32> = (0..d).map(|_| rng.f32() + 0.1).collect();
        let tree = build_tree(&phis, 1e-9);
        let target = 5;
        let q_t = tree.probability(&z, target);
        let (ids, probs) = tree.sample_negatives(&z, target, 200, &mut rng);
        assert_eq!(ids.len(), 200);
        for (&id, &q) in ids.iter().zip(&probs) {
            assert_ne!(id as usize, target);
            let want = tree.probability(&z, id as usize) / (1.0 - q_t);
            assert!((q - want).abs() < 1e-12, "id {id}: {q} vs {want}");
        }
    }

    #[test]
    fn memory_accounting() {
        let tree = KernelTree::new(1000, 64, 1e-6);
        // pad = 1024 → 1023 internal sums + total (× 64 × 4 bytes), plus
        // 1023 u32 live counts.
        assert_eq!(tree.memory_bytes(), (1023 + 1) * 64 * 4 + 1023 * 4);
    }

    #[test]
    fn estimate_bytes_matches_built_tree() {
        for &(n, dim) in &[(1usize, 4usize), (5, 3), (1000, 64), (1024, 16)] {
            let tree = KernelTree::new(n, dim, 1e-6);
            assert_eq!(
                KernelTree::estimate_bytes(n, dim),
                tree.memory_bytes(),
                "n={n} dim={dim}"
            );
        }
    }

    #[test]
    fn top_k_matches_probability_ranking() {
        check("tree-top-k-vs-brute", |rng| {
            let n = gen::usize_in(rng, 2, 60);
            let d = gen::usize_in(rng, 1, 6);
            // Mixed-sign features exercise the clamping path too.
            let phis: Vec<Vec<f32>> =
                (0..n).map(|_| gen::vector(rng, d)).collect();
            let z = gen::vector(rng, d);
            let tree = build_tree(&phis, 1e-6);
            let k = gen::usize_in(rng, 1, n.min(10));
            let got = tree.top_k(&z, k);
            let mut brute: Vec<(u32, f64)> = (0..n)
                .map(|i| (i as u32, tree.probability(&z, i)))
                .collect();
            brute.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            brute.truncate(k);
            prop_assert!(got.len() == k, "got {} of {k}", got.len());
            for (j, ((gi, gq), (bi, bq))) in
                got.iter().zip(&brute).enumerate()
            {
                // Probabilities must match exactly (same walk product);
                // ids may differ only on fp ties.
                prop_assert!(
                    close(*gq, *bq, 1e-9, 1e-15),
                    "rank {j}: q {gq} vs brute {bq}"
                );
                prop_assert!(
                    gi == bi || close(*gq, *bq, 1e-12, 1e-18),
                    "rank {j}: id {gi} vs {bi}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn top_k_full_list_is_whole_distribution() {
        let mut rng = Rng::seeded(97);
        let n = 13;
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..4).map(|_| rng.f32()).collect())
            .collect();
        let tree = build_tree(&phis, 1e-8);
        let z: Vec<f32> = (0..4).map(|_| rng.f32()).collect();
        let all = tree.top_k(&z, n + 10); // k clamps to n
        assert_eq!(all.len(), n);
        let total: f64 = all.iter().map(|(_, q)| q).sum();
        assert!((total - 1.0).abs() < 1e-9, "Σ top-k q = {total}");
        // Descending and duplicate-free.
        for w in all.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        let ids: std::collections::HashSet<_> =
            all.iter().map(|(i, _)| *i).collect();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn copy_state_from_replicates_distribution() {
        let mut rng = Rng::seeded(98);
        let n = 21;
        let d = 5;
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.f32()).collect())
            .collect();
        let src = build_tree(&phis, 1e-7);
        let mut dst = KernelTree::new(n, d, 1e-7);
        dst.copy_state_from(&src);
        let z: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        for i in 0..n {
            assert_eq!(src.probability(&z, i), dst.probability(&z, i));
        }
    }

    #[test]
    fn insert_grows_to_match_a_fresh_build() {
        // Start small, insert past several capacity doublings, and
        // require the grown tree to match a tree built directly on the
        // final class set — probabilities, Σq, and top-k.
        check("tree-insert-vs-rebuild", |rng| {
            let n0 = gen::usize_in(rng, 1, 6);
            let added = gen::usize_in(rng, 1, 30);
            let d = gen::usize_in(rng, 1, 6);
            let phis: Vec<Vec<f32>> = (0..n0 + added)
                .map(|_| (0..d).map(|_| rng.f32()).collect())
                .collect();
            let mut tree = build_tree(&phis[..n0], 1e-6);
            for (expect, phi) in phis.iter().enumerate().skip(n0) {
                prop_assert!(
                    tree.insert_class(phi) == expect,
                    "insert id mismatch"
                );
            }
            let rebuilt = build_tree(&phis, 1e-6);
            let z: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
            let mut total = 0.0;
            for i in 0..n0 + added {
                let a = tree.probability(&z, i);
                let b = rebuilt.probability(&z, i);
                prop_assert!(
                    close(a, b, 1e-3, 1e-7),
                    "class {i}: grown {a} vs rebuilt {b}"
                );
                total += a;
            }
            prop_assert!(close(total, 1.0, 1e-6, 1e-9), "Σq = {total}");
            Ok(())
        });
    }

    #[test]
    fn retired_classes_are_never_emitted_and_carry_zero_mass() {
        let mut rng = Rng::seeded(99);
        let n = 13;
        let d = 4;
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.f32() + 0.1).collect())
            .collect();
        let mut tree = build_tree(&phis, 1e-8);
        for &r in &[3usize, 7, 12] {
            tree.retire_class(r, &phis[r]);
        }
        assert_eq!(tree.live_classes(), n - 3);
        assert!(tree.is_retired(3) && !tree.is_retired(4));
        let z: Vec<f32> = (0..d).map(|_| rng.f32() + 0.1).collect();
        // Exact zero probability for holes; Σq over live slots is 1.
        for &r in &[3usize, 7, 12] {
            assert_eq!(tree.probability(&z, r), 0.0);
        }
        let total: f64 = (0..n).map(|i| tree.probability(&z, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
        // Draws and top-k avoid the holes; top_k clamps k to live.
        let (ids, _) = tree.sample_many(&z, 5000, &mut rng);
        assert!(ids.iter().all(|&i| !matches!(i, 3 | 7 | 12)));
        let all = tree.top_k(&z, n + 5);
        assert_eq!(all.len(), n - 3);
        assert!(all.iter().all(|&(i, _)| !matches!(i, 3 | 7 | 12)));
        // Negatives (incl. the live-aware uniform fallback path) too.
        let (nids, _) = tree.sample_negatives(&z, 5, 2000, &mut rng);
        assert!(nids.iter().all(|&i| !matches!(i, 3 | 7 | 12) && i != 5));
    }

    #[test]
    fn uniform_live_excluding_is_uniform_over_live_non_targets() {
        let mut rng = Rng::seeded(77);
        let n = 10;
        let phis: Vec<Vec<f32>> = (0..n).map(|_| vec![0.5f32, 0.5]).collect();
        let mut tree = build_tree(&phis, 1e-8);
        tree.retire_class(2, &phis[2]);
        tree.retire_class(8, &phis[8]);
        let target = 4usize;
        let trials = 40_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            counts[tree.uniform_live_excluding(Some(target), &mut rng)] += 1;
        }
        assert_eq!(counts[2] + counts[8] + counts[target], 0);
        let expect = trials as f64 / 7.0; // 10 − 2 retired − 1 target
        for (i, &c) in counts.iter().enumerate() {
            if matches!(i, 2 | 8) || i == target {
                continue;
            }
            assert!(
                (c as f64 - expect).abs() < 5.0 * expect.sqrt() + 5.0,
                "slot {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn churn_sequence_matches_scratch_rebuild_on_final_live_set() {
        // Interleave inserts, retires, and updates, then compare against
        // a tree built directly on the surviving class set (live slots in
        // id order) — the L1 version of the PR's acceptance criterion.
        let mut rng = Rng::seeded(173);
        let d = 5;
        let mut phis: Vec<Vec<f32>> =
            (0..8).map(|_| (0..d).map(|_| rng.f32()).collect()).collect();
        let mut retired: Vec<bool> = vec![false; 8];
        let mut tree = build_tree(&phis, 1e-7);
        for step in 0..40 {
            match step % 4 {
                0 | 1 => {
                    let phi: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
                    let id = tree.insert_class(&phi);
                    assert_eq!(id, phis.len());
                    phis.push(phi);
                    retired.push(false);
                }
                2 => {
                    let live: Vec<usize> = (0..phis.len())
                        .filter(|&i| !retired[i])
                        .collect();
                    if live.len() > 2 {
                        let pick = live[rng.index(live.len())];
                        tree.retire_class(pick, &phis[pick]);
                        retired[pick] = true;
                    }
                }
                _ => {
                    let live: Vec<usize> = (0..phis.len())
                        .filter(|&i| !retired[i])
                        .collect();
                    let pick = live[rng.index(live.len())];
                    let newphi: Vec<f32> =
                        (0..d).map(|_| rng.f32()).collect();
                    let delta: Vec<f32> = newphi
                        .iter()
                        .zip(&phis[pick])
                        .map(|(a, b)| a - b)
                        .collect();
                    tree.update_leaf(pick, &delta);
                    phis[pick] = newphi;
                }
            }
        }
        let live_ids: Vec<usize> =
            (0..phis.len()).filter(|&i| !retired[i]).collect();
        let live_phis: Vec<Vec<f32>> =
            live_ids.iter().map(|&i| phis[i].clone()).collect();
        let reference = build_tree(&live_phis, 1e-7);
        assert_eq!(tree.live_classes(), live_ids.len());
        let z: Vec<f32> = (0..d).map(|_| rng.f32()).collect();
        for (rank, &g) in live_ids.iter().enumerate() {
            let a = tree.probability(&z, g);
            let b = reference.probability(&z, rank);
            assert!(
                (a - b).abs() < 1e-3 * a.max(b).max(1e-7),
                "global {g} / rank {rank}: churned {a} vs rebuilt {b}"
            );
        }
    }

    #[test]
    fn with_capacity_pre_reservation_avoids_growth_copies() {
        let dim = 8;
        let mut reserved = KernelTree::with_capacity(5, dim, 1e-6, 64);
        let mut plain = KernelTree::new(5, dim, 1e-6);
        let phi_of = |i: usize| vec![0.01f32 * (i + 1) as f32; 8];
        for i in 0..5 {
            reserved.add_leaf(i, &phi_of(i));
            plain.add_leaf(i, &phi_of(i));
        }
        for i in 5..64 {
            assert_eq!(reserved.insert_class(&phi_of(i)), i);
            assert_eq!(plain.insert_class(&phi_of(i)), i);
        }
        assert_eq!(reserved.growths(), 0, "reservation must prevent doubling");
        assert!(plain.growths() > 0, "un-reserved tree must have doubled");
        // Both end at the same padded size and the same distribution.
        assert_eq!(
            reserved.memory_bytes(),
            KernelTree::estimate_bytes(64, dim)
        );
        assert_eq!(reserved.memory_bytes(), plain.memory_bytes());
        let z = vec![1.0f32; dim];
        for i in 0..64 {
            let a = reserved.probability(&z, i);
            let b = plain.probability(&z, i);
            assert!(
                (a - b).abs() < 1e-9 * a.max(b).max(1e-12),
                "class {i}: reserved {a} vs grown {b}"
            );
        }
        // A capacity at or below n is a no-op reservation.
        let same = KernelTree::with_capacity(5, dim, 1e-6, 3);
        assert_eq!(same.memory_bytes(), KernelTree::estimate_bytes(5, dim));
    }

    #[test]
    fn estimate_bytes_tracks_growth() {
        let mut tree = KernelTree::new(5, 8, 1e-6); // pad 8
        let before = tree.memory_bytes();
        assert_eq!(KernelTree::estimate_bytes(5, 8), before);
        for _ in 0..5 {
            tree.insert_class(&[0.1; 8]); // crosses 8 → 16
        }
        assert_eq!(tree.num_classes(), 10);
        assert_eq!(KernelTree::estimate_bytes(10, 8), tree.memory_bytes());
        assert!(tree.memory_bytes() > before);
    }

    #[test]
    fn non_pow2_never_samples_phantoms() {
        let mut rng = Rng::seeded(94);
        let n = 5; // pad = 8 → 3 phantom leaves
        let phis: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..3).map(|_| rng.f32()).collect())
            .collect();
        let tree = build_tree(&phis, 1e-6);
        let z = vec![1.0f32, 1.0, 1.0];
        for _ in 0..5000 {
            let (i, _) = tree.sample(&z, &mut rng);
            assert!(i < n);
        }
    }
}
