//! Two-level bucketed kernel sampler — bounded-memory variant of the
//! §3.1 sampling tree for very large `n × D` products (e.g. the Quadratic
//! baseline's `D = d²+1` features at n ≥ 200k, where a full per-node tree
//! would need tens of GB).
//!
//! Structure: classes are grouped into `⌈n/b⌉` buckets.
//!
//! * **Across buckets**: a [`KernelTree`] over the bucket φ-sums —
//!   `O(D log(n/b))` to pick a bucket.
//! * **Within a bucket**: the kernel `K(h, c_i)` is evaluated *directly*
//!   (via [`FeatureMap::exact_kernel`], `O(d)` per class — no feature
//!   vector needed), and a class is drawn by an `O(b)` clamped scan.
//!
//! The returned probability is exactly `P(bucket) · P(i | bucket)` of the
//! procedure that produced the sample, so the importance-weighted
//! partition estimate (paper eq. 5) stays unbiased; the distribution
//! equals the tree sampler's up to the feature map's approximation error
//! inside `P(bucket)` (exact for the quadratic map, whose linearization
//! is exact).
//!
//! Memory: `O((n/b)·D + n·d)` instead of `O(n·D)`.

use super::{BatchDraw, KernelTree, NegativeDraw, Sampler, VocabError};
use crate::featmap::FeatureMap;
use crate::linalg::Matrix;
use crate::rng::Rng;
use std::cell::RefCell;

const EPS: f64 = 1e-8;

/// `slot_of` sentinel for retired classes.
const RETIRED: u32 = u32::MAX;

pub struct BucketKernelSampler<M: FeatureMap> {
    map: M,
    /// Tree over bucket-level φ sums (bucket leaves retire when they
    /// drain and revive if the tail bucket refills on append).
    tree: KernelTree,
    classes: Matrix,
    bucket_size: usize,
    num_buckets: usize,
    /// Live class ids (swap-remove on retire) + inverse index — O(1)
    /// membership for the uniform fallback and hole masking.
    live_ids: Vec<u32>,
    slot_of: Vec<u32>,
    /// Live classes per bucket (bucket retires at 0).
    bucket_live: Vec<u32>,
    scratch: RefCell<Scratch>,
    name: &'static str,
}

struct Scratch {
    query: Vec<f32>,
    phi_old: Vec<f32>,
    phi_new: Vec<f32>,
    masses: Vec<f64>,
}

impl<M: FeatureMap> BucketKernelSampler<M> {
    pub fn with_map(
        classes: &Matrix,
        map: M,
        bucket_size: usize,
        name: &'static str,
    ) -> Self {
        assert!(bucket_size >= 1);
        let n = classes.rows();
        let dim = map.output_dim();
        let num_buckets = n.div_ceil(bucket_size);
        let mut tree = KernelTree::new(num_buckets, dim, EPS);
        let mut phi = vec![0.0f32; dim];
        let mut sum = vec![0.0f32; dim];
        for bkt in 0..num_buckets {
            sum.iter_mut().for_each(|v| *v = 0.0);
            let lo = bkt * bucket_size;
            let hi = (lo + bucket_size).min(n);
            for i in lo..hi {
                map.map_into(classes.row(i), &mut phi);
                for (s, p) in sum.iter_mut().zip(&phi) {
                    *s += p;
                }
            }
            tree.add_leaf(bkt, &sum);
        }
        let mut bucket_live = vec![bucket_size as u32; num_buckets];
        if num_buckets > 0 {
            bucket_live[num_buckets - 1] =
                (n - (num_buckets - 1) * bucket_size) as u32;
        }
        Self {
            map,
            tree,
            classes: classes.clone(),
            bucket_size,
            num_buckets,
            live_ids: (0..n as u32).collect(),
            slot_of: (0..n as u32).collect(),
            bucket_live,
            scratch: RefCell::new(Scratch {
                query: vec![0.0; dim],
                phi_old: vec![0.0; dim],
                phi_new: vec![0.0; dim],
                masses: vec![0.0; bucket_size],
            }),
            name,
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.tree.memory_bytes()
            + self.classes.data().len() * std::mem::size_of::<f32>()
    }

    pub fn num_buckets(&self) -> usize {
        self.num_buckets
    }

    fn bucket_range(&self, bkt: usize) -> (usize, usize) {
        let lo = bkt * self.bucket_size;
        (lo, (lo + self.bucket_size).min(self.classes.rows()))
    }

    /// Clamped within-bucket masses for query h; returns total. Retired
    /// classes contribute exactly 0 (no ε floor), so they are never
    /// picked by the in-bucket scan.
    fn bucket_masses(&self, h: &[f32], bkt: usize, masses: &mut Vec<f64>) -> f64 {
        let (lo, hi) = self.bucket_range(bkt);
        masses.clear();
        let mut total = 0.0;
        for i in lo..hi {
            let k = if self.slot_of[i] == RETIRED {
                0.0
            } else {
                self.map.exact_kernel(h, self.classes.row(i)).max(0.0) + EPS
            };
            masses.push(k);
            total += k;
        }
        total
    }

    /// One two-level draw for a pre-mapped query: `(class, q)`.
    fn draw_one(
        &self,
        query: &[f32],
        h: &[f32],
        rng: &mut Rng,
        masses: &mut Vec<f64>,
    ) -> (u32, f64) {
        let (bkt, q_bucket) = self.tree.sample(query, rng);
        let total = self.bucket_masses(h, bkt, masses);
        debug_assert!(total > 0.0, "drew a drained bucket {bkt}");
        let mut u = rng.f64() * total;
        let mut pick = usize::MAX;
        for (j, &w) in masses.iter().enumerate() {
            u -= w;
            if u < 0.0 && w > 0.0 {
                pick = j;
                break;
            }
        }
        if pick == usize::MAX {
            // fp boundary: fall back to the last positive-mass slot.
            pick = masses
                .iter()
                .rposition(|&w| w > 0.0)
                .expect("bucket with zero total mass");
        }
        let (lo, _) = self.bucket_range(bkt);
        ((lo + pick) as u32, q_bucket * masses[pick] / total)
    }

    /// Two-level probability for a pre-mapped query. Exact 0 for holes.
    fn probability_with_query(
        &self,
        query: &[f32],
        h: &[f32],
        class: usize,
        masses: &mut Vec<f64>,
    ) -> f64 {
        if self.slot_of[class] == RETIRED {
            return 0.0;
        }
        let bkt = class / self.bucket_size;
        let q_bucket = self.tree.probability(query, bkt);
        let total = self.bucket_masses(h, bkt, masses);
        let (lo, _) = self.bucket_range(bkt);
        q_bucket * masses[class - lo] / total
    }

    /// Negatives (`≠ target`) for a pre-mapped query, with the standard
    /// rejection + live-aware uniform fallback (never aborts, never
    /// emits holes).
    fn negatives_with_query(
        &self,
        query: &[f32],
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
        masses: &mut Vec<f64>,
    ) -> NegativeDraw {
        let live = self.live_ids.len();
        assert!(
            live > 1,
            "sample_negatives: need ≥ 2 live classes to exclude one"
        );
        let t_slot = self.slot_of[target];
        assert!(t_slot != RETIRED, "sample_negatives: retired target");
        let q_t = self.probability_with_query(query, h, target, masses);
        let renorm = (1.0 - q_t).max(f64::MIN_POSITIVE);
        let mut out = NegativeDraw::with_capacity(m);
        // Per-draw attempts rather than per-round: cap at m rounds' worth.
        let max_attempts = m.saturating_mul(super::REJECTION_ROUNDS).max(64);
        let mut attempts = 0usize;
        while out.ids.len() < m
            && attempts < max_attempts
            && q_t < super::DEGENERATE_Q
        {
            let (id, q) = self.draw_one(query, h, rng, masses);
            if id as usize != target {
                out.ids.push(id);
                out.probs.push(q / renorm);
            }
            attempts += 1;
        }
        while out.ids.len() < m {
            let pick = super::uniform_excluding(live, t_slot as usize, rng);
            out.ids.push(self.live_ids[pick]);
            out.probs.push(1.0 / (live - 1) as f64);
        }
        out
    }
}

impl<M: FeatureMap> Sampler for BucketKernelSampler<M> {
    fn num_classes(&self) -> usize {
        self.classes.rows()
    }

    fn live_classes(&self) -> usize {
        self.live_ids.len()
    }

    /// Append new classes. Each lands in the tail bucket (`id /
    /// bucket_size`): a fresh bucket inserts a new leaf into the
    /// bucket-level tree (capacity doubling as needed), a drained tail
    /// bucket revives, a live one just accumulates φ. `O(D log(n/b))`
    /// per class.
    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        if embeddings.rows() == 0 {
            return Ok(Vec::new());
        }
        super::validate_add_dim(embeddings.cols(), self.classes.cols())?;
        let mut ids = Vec::with_capacity(embeddings.rows());
        for r in 0..embeddings.rows() {
            let id = self.classes.rows();
            let bkt = id / self.bucket_size;
            let sc = self.scratch.get_mut();
            self.map.map_into(embeddings.row(r), &mut sc.phi_new);
            if bkt == self.num_buckets {
                let leaf = self.tree.insert_class(&sc.phi_new);
                debug_assert_eq!(leaf, bkt);
                self.num_buckets += 1;
                self.bucket_live.push(0);
            } else if self.bucket_live[bkt] == 0 && self.tree.is_retired(bkt)
            {
                self.tree.revive_class(bkt, &sc.phi_new);
            } else {
                self.tree.update_leaf(bkt, &sc.phi_new);
            }
            self.bucket_live[bkt] += 1;
            self.classes.push_row(embeddings.row(r));
            self.slot_of.push(self.live_ids.len() as u32);
            self.live_ids.push(id as u32);
            ids.push(id as u32);
        }
        Ok(ids)
    }

    /// Retire live classes: subtract φ from the bucket leaf, zero the
    /// in-bucket mass, and retire the bucket leaf itself when it drains.
    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        super::validate_retire(
            classes,
            self.classes.rows(),
            self.live_ids.len(),
            |c| self.slot_of[c] == RETIRED,
        )?;
        for &c in classes {
            let c = c as usize;
            let bkt = c / self.bucket_size;
            let sc = self.scratch.get_mut();
            self.map.map_into(self.classes.row(c), &mut sc.phi_old);
            for v in sc.phi_old.iter_mut() {
                *v = -*v;
            }
            self.tree.update_leaf(bkt, &sc.phi_old);
            self.bucket_live[bkt] -= 1;
            if self.bucket_live[bkt] == 0 {
                // Drained: retire the bucket leaf so its fp residue can
                // never be picked (subtraction of zero — the mass is
                // already gone).
                sc.phi_old.iter_mut().for_each(|v| *v = 0.0);
                self.tree.retire_class(bkt, &sc.phi_old);
            }
            let at = self.slot_of[c] as usize;
            self.live_ids.swap_remove(at);
            if at < self.live_ids.len() {
                self.slot_of[self.live_ids[at] as usize] = at as u32;
            }
            self.slot_of[c] = RETIRED;
        }
        Ok(())
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let mut sc = self.scratch.borrow_mut();
        let Scratch { query, masses, .. } = &mut *sc;
        self.map.map_into(h, query);
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            let (id, q) = self.draw_one(query, h, rng, masses);
            out.ids.push(id);
            out.probs.push(q);
        }
        out
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        let mut sc = self.scratch.borrow_mut();
        let Scratch { query, masses, .. } = &mut *sc;
        self.map.map_into(h, query);
        self.probability_with_query(query, h, class, masses)
    }

    fn sample_negatives(
        &self,
        h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        let mut sc = self.scratch.borrow_mut();
        let Scratch { query, masses, .. } = &mut *sc;
        self.map.map_into(h, query);
        self.negatives_with_query(query, h, target, m, rng, masses)
    }

    /// Batch override: every query mapped in one [`FeatureMap::map_batch`]
    /// call, then per-example two-level draws reusing one mass buffer.
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        let bsz = h.rows();
        assert_eq!(bsz, targets.len(), "sample_batch: batch mismatch");
        let queries = self.map.map_batch(h);
        let mut masses: Vec<f64> = Vec::with_capacity(self.bucket_size);
        let draws = (0..bsz)
            .map(|b| {
                self.negatives_with_query(
                    queries.row(b),
                    h.row(b),
                    targets[b] as usize,
                    m,
                    rng,
                    &mut masses,
                )
            })
            .collect();
        BatchDraw { draws }
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        assert!(
            self.slot_of[class] != RETIRED,
            "update_class: class {class} is retired"
        );
        let bkt = class / self.bucket_size;
        let sc = self.scratch.get_mut();
        self.map.map_into(self.classes.row(class), &mut sc.phi_old);
        self.map.map_into(embedding, &mut sc.phi_new);
        for (new, old) in sc.phi_new.iter_mut().zip(sc.phi_old.iter()) {
            *new -= old;
        }
        self.tree.update_leaf(bkt, &sc.phi_new);
        self.classes.row_mut(class).copy_from_slice(embedding);
    }

    fn name(&self) -> &'static str {
        self.name
    }

    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        Some(crate::snapshot::SamplerState::Bucket(
            crate::snapshot::BucketState {
                map_fingerprint: crate::snapshot::map_fingerprint(&self.map),
                tree: self.tree.to_state(),
                classes_cols: self.classes.cols(),
                classes: self.classes.data().to_vec(),
                bucket_size: self.bucket_size,
                num_buckets: self.num_buckets,
                live_ids: self.live_ids.clone(),
                slot_of: self.slot_of.clone(),
                bucket_live: self.bucket_live.clone(),
            },
        ))
    }

    /// Restore into this sampler as a skeleton (same map, any class
    /// content): the whole bucket structure — bucket-level tree, raw
    /// f32 class table, live/slot/bucket accounting — is swapped in
    /// wholesale after fingerprint + structural validation.
    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        use crate::snapshot::{SamplerState, SnapshotError};
        let SamplerState::Bucket(b) = state else {
            return Err(SnapshotError::Unsupported(
                "bucket sampler cannot restore a non-bucket snapshot",
            ));
        };
        state.validate()?;
        let computed = crate::snapshot::map_fingerprint(&self.map);
        if computed != b.map_fingerprint {
            return Err(SnapshotError::MapMismatch {
                stored: b.map_fingerprint,
                computed,
            });
        }
        if b.tree.dim != self.map.output_dim() {
            return Err(SnapshotError::Malformed(
                "bucket restore: tree dim != map output dim",
            ));
        }
        if b.classes_cols != self.map.input_dim() {
            return Err(SnapshotError::Malformed(
                "bucket restore: class cols != map input dim",
            ));
        }
        let tree = KernelTree::from_state(&b.tree)?;
        self.tree = tree;
        self.classes = Matrix::from_vec(
            b.classes.len() / b.classes_cols,
            b.classes_cols,
            b.classes.clone(),
        );
        self.bucket_size = b.bucket_size;
        self.num_buckets = b.num_buckets;
        self.live_ids = b.live_ids.clone();
        self.slot_of = b.slot_of.clone();
        self.bucket_live = b.bucket_live.clone();
        let dim = self.map.output_dim();
        self.scratch = RefCell::new(Scratch {
            query: vec![0.0; dim],
            phi_old: vec![0.0; dim],
            phi_new: vec![0.0; dim],
            masses: vec![0.0; self.bucket_size],
        });
        Ok(())
    }
}

unsafe impl<M: FeatureMap> Send for BucketKernelSampler<M> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featmap::QuadraticMap;
    use crate::linalg::{dot, unit_vector};

    fn setup(n: usize, d: usize, b: usize) -> (Matrix, BucketKernelSampler<QuadraticMap>) {
        let mut rng = Rng::seeded(161);
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let map = QuadraticMap::new(d, 100.0, 1.0);
        let s = BucketKernelSampler::with_map(&classes, map, b, "quadratic-bucket");
        (classes, s)
    }

    #[test]
    fn matches_exact_quadratic_distribution() {
        // For the quadratic map P(bucket) is exact, so the two-level
        // probability must equal the global kernel distribution.
        let (classes, s) = setup(37, 8, 5);
        let mut rng = Rng::seeded(162);
        let h = unit_vector(&mut rng, 8);
        let k: Vec<f64> = (0..37)
            .map(|i| {
                let v = dot(&h, classes.row(i)) as f64;
                100.0 * v * v + 1.0
            })
            .collect();
        let tot: f64 = k.iter().sum();
        let mut qsum = 0.0;
        for i in 0..37 {
            let q = s.probability(&h, i);
            let want = k[i] / tot;
            assert!(
                (q - want).abs() < 2e-3 * want.max(1e-6),
                "class {i}: {q} vs {want}"
            );
            qsum += q;
        }
        assert!((qsum - 1.0).abs() < 1e-6, "Σq = {qsum}");
    }

    #[test]
    fn sampling_frequency_matches_probability() {
        let (_, s) = setup(20, 6, 4);
        let mut rng = Rng::seeded(163);
        let h = unit_vector(&mut rng, 6);
        let trials = 100_000;
        let draw = s.sample(&h, trials, &mut rng);
        let mut counts = vec![0usize; 20];
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
        for i in 0..20 {
            let q = s.probability(&h, i);
            let freq = counts[i] as f64 / trials as f64;
            let sd = (q * (1.0 - q) / trials as f64).sqrt();
            assert!(
                (freq - q).abs() < 5.0 * sd + 1e-3,
                "class {i}: freq {freq} vs q {q}"
            );
        }
    }

    #[test]
    fn update_propagates_both_levels() {
        let (_, mut s) = setup(24, 6, 4);
        let mut rng = Rng::seeded(164);
        let h = unit_vector(&mut rng, 6);
        let before = s.probability(&h, 10);
        s.update_class(10, &h); // align with query → kernel value jumps
        let after = s.probability(&h, 10);
        assert!(after > before, "{before} → {after}");
        // Distribution still normalized.
        let qsum: f64 = (0..24).map(|i| s.probability(&h, i)).sum();
        assert!((qsum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn sample_batch_matches_conditioned_probabilities() {
        let (_, s) = setup(30, 6, 4);
        let mut rng = Rng::seeded(166);
        let bsz = 4;
        let mut h = Matrix::zeros(bsz, 6);
        for b in 0..bsz {
            let v = unit_vector(&mut rng, 6);
            h.row_mut(b).copy_from_slice(&v);
        }
        let targets = [3u32, 11, 19, 27];
        let batch = s.sample_batch(&h, &targets, 25, &mut rng);
        assert_eq!(batch.batch(), bsz);
        for (b, draw) in batch.draws.iter().enumerate() {
            assert_eq!(draw.len(), 25);
            let t = targets[b] as usize;
            let q_t = s.probability(h.row(b), t);
            for (&id, &q) in draw.ids.iter().zip(&draw.probs) {
                assert_ne!(id as usize, t);
                let want =
                    s.probability(h.row(b), id as usize) / (1.0 - q_t);
                assert!(
                    (q - want).abs() < 1e-9 * want.max(1e-12),
                    "example {b} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn bucket_churn_matches_scratch_rebuild_and_skips_holes() {
        let (classes, mut s) = setup(17, 6, 4);
        let mut rng = Rng::seeded(167);
        let mut all = classes.clone();
        // Add 9 classes: fills the tail bucket and opens two more
        // (17 → 26 over bucket_size 4 ⇒ buckets 5 → 7).
        let mut add = Matrix::zeros(9, 6);
        for r in 0..9 {
            let v = unit_vector(&mut rng, 6);
            add.row_mut(r).copy_from_slice(&v);
            all.push_row(add.row(r));
        }
        let ids = s.add_classes(&add).unwrap();
        assert_eq!(ids, (17u32..26).collect::<Vec<_>>());
        assert_eq!(s.num_buckets(), 7);
        // Retire one whole interior bucket (ids 4..8), a straggler, and
        // the ENTIRE tail bucket (ids 24..26) to set up revival below.
        s.retire_classes(&[4, 5, 6, 7, 12, 24, 25]).unwrap();
        assert_eq!(s.num_classes(), 26);
        assert_eq!(s.live_classes(), 19);
        assert!(s.retire_classes(&[4]).is_err(), "double retire");

        let h = unit_vector(&mut rng, 6);
        let retired = [4usize, 5, 6, 7, 12, 24, 25];
        for &r in &retired {
            assert_eq!(s.probability(&h, r), 0.0, "hole {r}");
        }
        let total: f64 =
            (0..26).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
        // The quadratic bucket probability is exact, so survivors must
        // match a from-scratch sampler on the live set.
        let live_ids: Vec<usize> =
            (0..26).filter(|i| !retired.contains(i)).collect();
        let mut live_mat = Matrix::zeros(0, 6);
        for &g in &live_ids {
            live_mat.push_row(all.row(g));
        }
        let reference = BucketKernelSampler::with_map(
            &live_mat,
            QuadraticMap::new(6, 100.0, 1.0),
            4,
            "quadratic-bucket",
        );
        for (rank, &g) in live_ids.iter().enumerate() {
            let a = s.probability(&h, g);
            let b = reference.probability(&h, rank);
            assert!(
                (a - b).abs() < 1e-3 * a.max(b).max(1e-7),
                "global {g} / rank {rank}: churned {a} vs rebuilt {b}"
            );
        }
        // Draws + negatives (incl. the uniform fallback path) skip holes.
        let draw = s.sample(&h, 20_000, &mut rng);
        assert!(draw.ids.iter().all(|&i| !retired.contains(&(i as usize))));
        let negs = s.sample_negatives(&h, 0, 2000, &mut rng);
        assert!(negs.ids.iter().all(|&i| {
            i != 0 && !retired.contains(&(i as usize))
        }));
        // Tail-bucket revival: bucket 6 (ids 24..26) fully drained above,
        // so this append must revive its bucket-level leaf.
        let mut one = Matrix::zeros(1, 6);
        let v = unit_vector(&mut rng, 6);
        one.row_mut(0).copy_from_slice(&v);
        let revived = s.add_classes(&one).unwrap();
        assert_eq!(revived, vec![26]);
        assert!(s.probability(&h, 26) > 0.0);
        let total: f64 =
            (0..27).map(|i| s.probability(&h, i)).sum();
        assert!((total - 1.0).abs() < 1e-6, "post-revival Σq = {total}");
    }

    #[test]
    fn memory_is_bounded_by_buckets() {
        let (_, coarse) = setup(512, 8, 128);
        let (_, fine) = setup(512, 8, 2);
        assert!(coarse.memory_bytes() < fine.memory_bytes());
    }

    #[test]
    fn bucket_size_one_equals_tree_semantics() {
        let (_, s) = setup(9, 4, 1);
        let mut rng = Rng::seeded(165);
        let h = unit_vector(&mut rng, 4);
        let qsum: f64 = (0..9).map(|i| s.probability(&h, i)).sum();
        assert!((qsum - 1.0).abs() < 1e-6);
        let draw = s.sample(&h, 50, &mut rng);
        assert_eq!(draw.len(), 50);
    }
}
