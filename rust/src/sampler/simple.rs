//! Input-independent baselines (UNIFORM, log-uniform, unigram/alias) plus
//! the two `O(dn)` oracles: the EXP baseline (exact softmax sampling) and
//! the Gumbel-top-k extension.

use super::{NegativeDraw, Sampler};
use crate::linalg::{dot, Matrix};
use crate::rng::{AliasTable, Rng};

/// UNIFORM baseline: `q_i = 1/n`, `O(1)` per draw.
pub struct UniformSampler {
    n: usize,
}

impl UniformSampler {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { n }
    }
}

impl Sampler for UniformSampler {
    fn num_classes(&self) -> usize {
        self.n
    }

    fn sample(&self, _h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let q = 1.0 / self.n as f64;
        NegativeDraw {
            ids: (0..m).map(|_| rng.index(self.n) as u32).collect(),
            probs: vec![q; m],
        }
    }

    fn probability(&self, _h: &[f32], _class: usize) -> f64 {
        1.0 / self.n as f64
    }

    fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Log-uniform (Zipfian rank) prior, the classic language-model negative
/// sampler: `P(k) = log((k+2)/(k+1)) / log(n+1)`. Assumes class ids are
/// ordered by decreasing frequency (true for our synthetic corpora).
/// Sampling is `O(1)` by analytic inverse CDF.
pub struct LogUniformSampler {
    n: usize,
    log_n1: f64,
}

impl LogUniformSampler {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { n, log_n1: ((n + 1) as f64).ln() }
    }
}

impl Sampler for LogUniformSampler {
    fn num_classes(&self) -> usize {
        self.n
    }

    fn sample(&self, _h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            // CDF(k) = log(k+2)/log(n+1) ⇒ k = ⌊e^{u·log(n+1)}⌋ − 1.
            let u = rng.f64();
            let k = ((u * self.log_n1).exp() as usize)
                .saturating_sub(1)
                .min(self.n - 1);
            out.ids.push(k as u32);
            out.probs.push(self.probability(&[], k));
        }
        out
    }

    fn probability(&self, _h: &[f32], class: usize) -> f64 {
        (((class + 2) as f64).ln() - ((class + 1) as f64).ln()) / self.log_n1
    }

    fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

    fn name(&self) -> &'static str {
        "loguniform"
    }
}

/// Static prior over classes (e.g. the empirical unigram distribution)
/// via a Walker alias table: `O(1)` per draw.
pub struct AliasSampler {
    table: AliasTable,
}

impl AliasSampler {
    pub fn new(weights: &[f64]) -> Self {
        Self { table: AliasTable::new(weights) }
    }
}

impl Sampler for AliasSampler {
    fn num_classes(&self) -> usize {
        self.table.len()
    }

    fn sample(&self, _h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            let i = self.table.sample(rng);
            out.ids.push(i as u32);
            out.probs.push(self.table.probability(i));
        }
        out
    }

    fn probability(&self, _h: &[f32], class: usize) -> f64 {
        self.table.probability(class)
    }

    fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

    fn name(&self) -> &'static str {
        "unigram"
    }
}

/// EXP baseline: sample *exactly* from the softmax distribution
/// `q_i ∝ exp(τ hᵀc_i)` by computing all n logits — `O(dn)` per call,
/// the cost RF-softmax exists to avoid. Gradient-wise this is the gold
/// standard (Theorem 1: zero bias).
pub struct ExactSoftmaxSampler {
    classes: Matrix,
    tau: f32,
}

impl ExactSoftmaxSampler {
    pub fn new(classes: &Matrix, tau: f32) -> Self {
        assert!(tau > 0.0);
        Self { classes: classes.clone(), tau }
    }

    /// Full softmax pmf for a query (shared by sample/probability).
    fn pmf(&self, h: &[f32]) -> Vec<f64> {
        let n = self.classes.rows();
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            logits.push((self.tau * dot(h, self.classes.row(i))) as f64);
        }
        crate::linalg::softmax(&logits)
    }
}

impl Sampler for ExactSoftmaxSampler {
    fn num_classes(&self) -> usize {
        self.classes.rows()
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let p = self.pmf(h);
        // Alias table amortizes the m draws after the O(dn) logit pass.
        let table = AliasTable::new(&p);
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            let i = table.sample(rng);
            out.ids.push(i as u32);
            out.probs.push(p[i]);
        }
        out
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        self.pmf(h)[class]
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        self.classes.row_mut(class).copy_from_slice(embedding);
    }

    fn name(&self) -> &'static str {
        "exp"
    }
}

/// Gumbel-top-k extension (paper §1.1, ref [13]): perturb all logits with
/// i.i.d. Gumbel noise and take the top `m` — a sample of m *distinct*
/// classes whose marginal inclusion tracks the softmax distribution.
/// Reported probabilities are the softmax marginals (the standard
/// practical surrogate; exact subset probabilities are intractable).
pub struct GumbelTopKSampler {
    classes: Matrix,
    tau: f32,
}

impl GumbelTopKSampler {
    pub fn new(classes: &Matrix, tau: f32) -> Self {
        assert!(tau > 0.0);
        Self { classes: classes.clone(), tau }
    }
}

impl Sampler for GumbelTopKSampler {
    fn num_classes(&self) -> usize {
        self.classes.rows()
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let n = self.classes.rows();
        assert!(m <= n, "GumbelTopK: m > n");
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            logits.push((self.tau * dot(h, self.classes.row(i))) as f64);
        }
        let p = crate::linalg::softmax(&logits);
        // Perturb and select top-m by partial sort.
        let mut keyed: Vec<(f64, u32)> = logits
            .iter()
            .enumerate()
            .map(|(i, &o)| (o + rng.gumbel(), i as u32))
            .collect();
        keyed.select_nth_unstable_by(m - 1, |a, b| {
            b.0.partial_cmp(&a.0).unwrap()
        });
        keyed.truncate(m);
        let mut out = NegativeDraw::with_capacity(m);
        for (_, i) in keyed {
            out.ids.push(i);
            out.probs.push(p[i as usize]);
        }
        out
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        let n = self.classes.rows();
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            logits.push((self.tau * dot(h, self.classes.row(i))) as f64);
        }
        crate::linalg::softmax(&logits)[class]
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        self.classes.row_mut(class).copy_from_slice(embedding);
    }

    fn name(&self) -> &'static str {
        "gumbel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;
    use crate::sampler::tests::chi2_check;

    #[test]
    fn uniform_probabilities() {
        let s = UniformSampler::new(100);
        assert!((s.probability(&[], 42) - 0.01).abs() < 1e-12);
        let mut rng = Rng::seeded(111);
        chi2_check(&s, &[], 100_000, &mut rng, 5.0);
    }

    #[test]
    fn loguniform_pmf_sums_to_one_and_is_decreasing() {
        let s = LogUniformSampler::new(1000);
        let total: f64 = (0..1000).map(|i| s.probability(&[], i)).sum();
        assert!((total - 1.0).abs() < 1e-9, "Σq = {total}");
        assert!(s.probability(&[], 0) > s.probability(&[], 999));
    }

    #[test]
    fn loguniform_empirical_matches_pmf() {
        let s = LogUniformSampler::new(50);
        let mut rng = Rng::seeded(112);
        chi2_check(&s, &[], 200_000, &mut rng, 5.0);
    }

    #[test]
    fn alias_sampler_matches_weights() {
        let w = vec![1.0, 5.0, 0.5, 2.0, 1.5];
        let s = AliasSampler::new(&w);
        let mut rng = Rng::seeded(113);
        chi2_check(&s, &[], 100_000, &mut rng, 5.0);
    }

    #[test]
    fn exact_softmax_matches_brute_force() {
        let mut rng = Rng::seeded(114);
        let n = 30;
        let d = 8;
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let s = ExactSoftmaxSampler::new(&classes, 3.0);
        let h = unit_vector(&mut rng, d);
        // Direct softmax check.
        // Match the sampler's f32 multiply-then-cast order exactly.
        let logits: Vec<f64> = (0..n)
            .map(|i| (3.0f32 * dot(&h, classes.row(i))) as f64)
            .collect();
        let p = crate::linalg::softmax(&logits);
        for i in 0..n {
            assert!((s.probability(&h, i) - p[i]).abs() < 1e-9);
        }
        chi2_check(&s, &h, 100_000, &mut rng, 5.0);
    }

    #[test]
    fn exact_softmax_update_changes_pmf() {
        let mut rng = Rng::seeded(115);
        let classes = Matrix::randn(&mut rng, 10, 4).l2_normalized_rows();
        let mut s = ExactSoftmaxSampler::new(&classes, 5.0);
        let h = unit_vector(&mut rng, 4);
        let before = s.probability(&h, 2);
        s.update_class(2, &h); // align class 2 with h
        assert!(s.probability(&h, 2) > before);
    }

    #[test]
    fn gumbel_returns_distinct_classes() {
        let mut rng = Rng::seeded(116);
        let classes = Matrix::randn(&mut rng, 40, 6).l2_normalized_rows();
        let s = GumbelTopKSampler::new(&classes, 4.0);
        let h = unit_vector(&mut rng, 6);
        let draw = s.sample(&h, 15, &mut rng);
        assert_eq!(draw.len(), 15);
        let set: std::collections::HashSet<_> = draw.ids.iter().collect();
        assert_eq!(set.len(), 15, "gumbel-top-k must be distinct");
    }

    #[test]
    fn gumbel_favors_high_logit_classes() {
        let mut rng = Rng::seeded(117);
        let d = 6;
        let mut classes = Matrix::randn(&mut rng, 20, d).l2_normalized_rows();
        let h = unit_vector(&mut rng, d);
        classes.row_mut(5).copy_from_slice(&h); // class 5 = argmax logit
        let s = GumbelTopKSampler::new(&classes, 10.0);
        let mut hits = 0;
        let trials = 300;
        for _ in 0..trials {
            let draw = s.sample(&h, 3, &mut rng);
            if draw.ids.contains(&5) {
                hits += 1;
            }
        }
        assert!(
            hits > trials * 8 / 10,
            "top class included only {hits}/{trials} times"
        );
    }

    #[test]
    fn sample_negatives_renormalizes() {
        // For uniform over n classes excluding t, q' must be 1/(n-1)·…
        // — exactly q/(1-q_t).
        let s = UniformSampler::new(10);
        let mut rng = Rng::seeded(118);
        let draw = s.sample_negatives(&[], 3, 1000, &mut rng);
        assert!(draw.ids.iter().all(|&i| i != 3));
        for &q in &draw.probs {
            assert!((q - (0.1 / 0.9)).abs() < 1e-12);
        }
    }
}
