//! Input-independent baselines (UNIFORM, log-uniform, unigram/alias) plus
//! the two `O(dn)` oracles: the EXP baseline (exact softmax sampling) and
//! the Gumbel-top-k extension.

use super::{
    uniform_excluding, BatchDraw, NegativeDraw, Sampler, ServeSampler,
    VocabError,
};
use crate::linalg::{dot, Matrix};
use crate::rng::{AliasTable, Rng};

/// UNIFORM baseline: `q_i = 1/live`, `O(1)` per draw. Supports the
/// mutable class universe: adds append slots, retires leave permanent
/// zero-probability holes (the live-id list + inverse index keep draws
/// `O(1)` and hole-free).
#[derive(Clone)]
pub struct UniformSampler {
    /// Live slot ids (order irrelevant; swap-remove on retire).
    live: Vec<u32>,
    /// Slot id → index into `live`, `u32::MAX` once retired.
    index: Vec<u32>,
}

const RETIRED: u32 = u32::MAX;

impl UniformSampler {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self {
            live: (0..n as u32).collect(),
            index: (0..n as u32).collect(),
        }
    }

    fn is_retired(&self, class: usize) -> bool {
        self.index[class] == RETIRED
    }
}

impl Sampler for UniformSampler {
    fn num_classes(&self) -> usize {
        self.index.len()
    }

    fn live_classes(&self) -> usize {
        self.live.len()
    }

    fn add_classes(&mut self, embeddings: &Matrix) -> Result<Vec<u32>, VocabError> {
        // Input-independent: only the row count matters.
        let mut ids = Vec::with_capacity(embeddings.rows());
        for _ in 0..embeddings.rows() {
            let id = self.index.len() as u32;
            self.index.push(self.live.len() as u32);
            self.live.push(id);
            ids.push(id);
        }
        Ok(ids)
    }

    fn retire_classes(&mut self, classes: &[u32]) -> Result<(), VocabError> {
        // Shared up-front validation: a bad id mutates nothing.
        super::validate_retire(
            classes,
            self.index.len(),
            self.live.len(),
            |c| self.index[c] == RETIRED,
        )?;
        for &c in classes {
            // Swap-remove from the live list, patching the swapped id's
            // inverse entry.
            let at = self.index[c as usize] as usize;
            self.live.swap_remove(at);
            if at < self.live.len() {
                self.index[self.live[at] as usize] = at as u32;
            }
            self.index[c as usize] = RETIRED;
        }
        Ok(())
    }

    fn sample(&self, _h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let q = 1.0 / self.live.len() as f64;
        NegativeDraw {
            ids: (0..m)
                .map(|_| self.live[rng.index(self.live.len())])
                .collect(),
            probs: vec![q; m],
        }
    }

    fn probability(&self, _h: &[f32], class: usize) -> f64 {
        if self.is_retired(class) {
            0.0
        } else {
            1.0 / self.live.len() as f64
        }
    }

    /// Direct conditioned draw over the live list (the trait default's
    /// rejection loop would fall back to a flat `uniform_excluding(n)`
    /// that can emit retired holes once the universe has them).
    fn sample_negatives(
        &self,
        _h: &[f32],
        target: usize,
        m: usize,
        rng: &mut Rng,
    ) -> NegativeDraw {
        assert!(
            self.live.len() > 1,
            "sample_negatives: need ≥ 2 live classes to exclude one"
        );
        let slot = self.index[target];
        assert!(slot != RETIRED, "sample_negatives: retired target {target}");
        let q = 1.0 / (self.live.len() - 1) as f64;
        NegativeDraw {
            ids: (0..m)
                .map(|_| {
                    self.live[uniform_excluding(
                        self.live.len(),
                        slot as usize,
                        rng,
                    )]
                })
                .collect(),
            probs: vec![q; m],
        }
    }

    /// Batch override: direct uniform-excluding-target draws over the
    /// live list — exactly the conditioned distribution
    /// `q/(1 − q_t) = 1/(live−1)`, with no rejection loop at all.
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        assert_eq!(h.rows(), targets.len(), "sample_batch: batch mismatch");
        assert!(self.live.len() > 1, "sample_batch: need ≥ 2 live classes");
        let q = 1.0 / (self.live.len() - 1) as f64;
        let draws = targets
            .iter()
            .map(|&t| {
                let slot = self.index[t as usize];
                assert!(slot != RETIRED, "sample_batch: retired target {t}");
                NegativeDraw {
                    ids: (0..m)
                        .map(|_| {
                            self.live[uniform_excluding(
                                self.live.len(),
                                slot as usize,
                                rng,
                            )]
                        })
                        .collect(),
                    probs: vec![q; m],
                }
            })
            .collect();
        BatchDraw { draws }
    }

    fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn name(&self) -> &'static str {
        "uniform"
    }

    fn snapshot_state(&self) -> Option<crate::snapshot::SamplerState> {
        Some(crate::snapshot::SamplerState::Uniform(
            crate::snapshot::UniformState {
                live: self.live.clone(),
                index: self.index.clone(),
            },
        ))
    }

    fn restore_state(
        &mut self,
        state: &crate::snapshot::SamplerState,
    ) -> Result<(), crate::snapshot::SnapshotError> {
        let crate::snapshot::SamplerState::Uniform(u) = state else {
            return Err(crate::snapshot::SnapshotError::Unsupported(
                "uniform sampler cannot restore a non-uniform snapshot",
            ));
        };
        state.validate()?;
        self.live = u.live.clone();
        self.index = u.index.clone();
        Ok(())
    }
}

/// Log-uniform (Zipfian rank) prior, the classic language-model negative
/// sampler: `P(k) = log((k+2)/(k+1)) / log(n+1)`. Assumes class ids are
/// ordered by decreasing frequency (true for our synthetic corpora).
/// Sampling is `O(1)` by analytic inverse CDF.
#[derive(Clone)]
pub struct LogUniformSampler {
    n: usize,
    log_n1: f64,
}

impl LogUniformSampler {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { n, log_n1: ((n + 1) as f64).ln() }
    }
}

impl Sampler for LogUniformSampler {
    fn num_classes(&self) -> usize {
        self.n
    }

    fn sample(&self, _h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            // CDF(k) = log(k+2)/log(n+1) ⇒ k = ⌊e^{u·log(n+1)}⌋ − 1.
            let u = rng.f64();
            let k = ((u * self.log_n1).exp() as usize)
                .saturating_sub(1)
                .min(self.n - 1);
            out.ids.push(k as u32);
            out.probs.push(self.probability(&[], k));
        }
        out
    }

    fn probability(&self, _h: &[f32], class: usize) -> f64 {
        (((class + 2) as f64).ln() - ((class + 1) as f64).ln()) / self.log_n1
    }

    fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn name(&self) -> &'static str {
        "loguniform"
    }
}

/// Static prior over classes (e.g. the empirical unigram distribution)
/// via a Walker alias table: `O(1)` per draw.
#[derive(Clone)]
pub struct AliasSampler {
    table: AliasTable,
}

impl AliasSampler {
    pub fn new(weights: &[f64]) -> Self {
        Self { table: AliasTable::new(weights) }
    }
}

impl Sampler for AliasSampler {
    fn num_classes(&self) -> usize {
        self.table.len()
    }

    fn sample(&self, _h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            let i = self.table.sample(rng);
            out.ids.push(i as u32);
            out.probs.push(self.table.probability(i));
        }
        out
    }

    fn probability(&self, _h: &[f32], class: usize) -> f64 {
        self.table.probability(class)
    }

    fn update_class(&mut self, _class: usize, _embedding: &[f32]) {}

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn name(&self) -> &'static str {
        "unigram"
    }
}

/// EXP baseline: sample *exactly* from the softmax distribution
/// `q_i ∝ exp(τ hᵀc_i)` by computing all n logits — `O(dn)` per call,
/// the cost RF-softmax exists to avoid. Gradient-wise this is the gold
/// standard (Theorem 1: zero bias).
#[derive(Clone)]
pub struct ExactSoftmaxSampler {
    classes: Matrix,
    tau: f32,
}

impl ExactSoftmaxSampler {
    pub fn new(classes: &Matrix, tau: f32) -> Self {
        assert!(tau > 0.0);
        Self { classes: classes.clone(), tau }
    }

    /// Full softmax pmf for a query (shared by sample/probability).
    fn pmf(&self, h: &[f32]) -> Vec<f64> {
        let n = self.classes.rows();
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            logits.push((self.tau * dot(h, self.classes.row(i))) as f64);
        }
        crate::linalg::softmax(&logits)
    }
}

impl Sampler for ExactSoftmaxSampler {
    fn num_classes(&self) -> usize {
        self.classes.rows()
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let p = self.pmf(h);
        // Alias table amortizes the m draws after the O(dn) logit pass.
        let table = AliasTable::new(&p);
        let mut out = NegativeDraw::with_capacity(m);
        for _ in 0..m {
            let i = table.sample(rng);
            out.ids.push(i as u32);
            out.probs.push(p[i]);
        }
        out
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        self.pmf(h)[class]
    }

    /// Batch override: all `batch × n` logits from one blocked gemm
    /// (`H · Cᵀ`), then per example an alias table over the pmf with the
    /// target zeroed — direct conditioned sampling, no rejection, exact
    /// `q/(1 − q_t)` probabilities.
    fn sample_batch(
        &self,
        h: &Matrix,
        targets: &[u32],
        m: usize,
        rng: &mut Rng,
    ) -> BatchDraw {
        let bsz = h.rows();
        assert_eq!(bsz, targets.len(), "sample_batch: batch mismatch");
        assert_eq!(h.cols(), self.classes.cols(), "sample_batch: query dim");
        let n = self.classes.rows();
        assert!(n > 1, "sample_batch: need ≥ 2 classes");
        let scores = h.matmul_nt(&self.classes);
        let mut draws = Vec::with_capacity(bsz);
        for b in 0..bsz {
            // Same f32-multiply-then-cast order as `pmf` for bit parity.
            let logits: Vec<f64> = scores
                .row(b)
                .iter()
                .map(|&s| (self.tau * s) as f64)
                .collect();
            let p = crate::linalg::softmax(&logits);
            let t = targets[b] as usize;
            let renorm = 1.0 - p[t];
            let mut out = NegativeDraw::with_capacity(m);
            if renorm > 1e-12 {
                let mut w = p.clone();
                w[t] = 0.0;
                let table = AliasTable::new(&w);
                for _ in 0..m {
                    let i = table.sample(rng);
                    out.ids.push(i as u32);
                    out.probs.push(p[i] / renorm);
                }
            } else {
                // Degenerate: essentially all mass on the target.
                for _ in 0..m {
                    out.ids.push(uniform_excluding(n, t, rng) as u32);
                    out.probs.push(1.0 / (n - 1) as f64);
                }
            }
            draws.push(out);
        }
        BatchDraw { draws }
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        self.classes.row_mut(class).copy_from_slice(embedding);
    }

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn name(&self) -> &'static str {
        "exp"
    }
}

/// Gumbel-top-k extension (paper §1.1, ref [13]): perturb all logits with
/// i.i.d. Gumbel noise and take the top `m` — a sample of m *distinct*
/// classes whose marginal inclusion tracks the softmax distribution.
/// Reported probabilities are the softmax marginals (the standard
/// practical surrogate; exact subset probabilities are intractable).
#[derive(Clone)]
pub struct GumbelTopKSampler {
    classes: Matrix,
    tau: f32,
}

impl GumbelTopKSampler {
    pub fn new(classes: &Matrix, tau: f32) -> Self {
        assert!(tau > 0.0);
        Self { classes: classes.clone(), tau }
    }
}

impl Sampler for GumbelTopKSampler {
    fn num_classes(&self) -> usize {
        self.classes.rows()
    }

    fn sample(&self, h: &[f32], m: usize, rng: &mut Rng) -> NegativeDraw {
        let n = self.classes.rows();
        assert!(m <= n, "GumbelTopK: m > n");
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            logits.push((self.tau * dot(h, self.classes.row(i))) as f64);
        }
        let p = crate::linalg::softmax(&logits);
        // Perturb and select top-m by partial sort.
        let mut keyed: Vec<(f64, u32)> = logits
            .iter()
            .enumerate()
            .map(|(i, &o)| (o + rng.gumbel(), i as u32))
            .collect();
        keyed.select_nth_unstable_by(m - 1, |a, b| {
            b.0.partial_cmp(&a.0).unwrap()
        });
        keyed.truncate(m);
        let mut out = NegativeDraw::with_capacity(m);
        for (_, i) in keyed {
            out.ids.push(i);
            out.probs.push(p[i as usize]);
        }
        out
    }

    fn probability(&self, h: &[f32], class: usize) -> f64 {
        let n = self.classes.rows();
        let mut logits = Vec::with_capacity(n);
        for i in 0..n {
            logits.push((self.tau * dot(h, self.classes.row(i))) as f64);
        }
        crate::linalg::softmax(&logits)[class]
    }

    fn update_class(&mut self, class: usize, embedding: &[f32]) {
        self.classes.row_mut(class).copy_from_slice(embedding);
    }

    fn fork(&self) -> Option<Box<dyn ServeSampler>> {
        Some(Box::new(self.clone()))
    }

    fn name(&self) -> &'static str {
        "gumbel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::unit_vector;
    use crate::sampler::tests::chi2_check;

    #[test]
    fn uniform_probabilities() {
        let s = UniformSampler::new(100);
        assert!((s.probability(&[], 42) - 0.01).abs() < 1e-12);
        let mut rng = Rng::seeded(111);
        chi2_check(&s, &[], 100_000, &mut rng, 5.0);
    }

    #[test]
    fn uniform_churn_stays_uniform_over_live() {
        let mut s = UniformSampler::new(6);
        let added = s.add_classes(&Matrix::zeros(4, 1)).unwrap();
        assert_eq!(added, vec![6, 7, 8, 9]);
        s.retire_classes(&[1, 7, 9]).unwrap();
        assert_eq!(s.num_classes(), 10);
        assert_eq!(s.live_classes(), 7);
        assert!(s.retire_classes(&[1]).is_err(), "double retire");
        assert!(s.retire_classes(&[0, 0]).is_err(), "duplicate");
        for &r in &[1usize, 7, 9] {
            assert_eq!(s.probability(&[], r), 0.0);
        }
        let total: f64 = (0..10).map(|i| s.probability(&[], i)).sum();
        assert!((total - 1.0).abs() < 1e-12, "Σq = {total}");
        let mut rng = Rng::seeded(140);
        let draw = s.sample(&[], 20_000, &mut rng);
        assert!(draw.ids.iter().all(|&i| !matches!(i, 1 | 7 | 9)));
        assert!(draw.probs.iter().all(|&q| (q - 1.0 / 7.0).abs() < 1e-12));
        chi2_check(&s, &[], 100_000, &mut rng, 5.0);
        // Conditioned batch draws skip holes and the target.
        let batch = s.sample_batch(&Matrix::zeros(1, 1), &[4], 5000, &mut rng);
        assert!(batch.draws[0]
            .ids
            .iter()
            .all(|&i| !matches!(i, 1 | 4 | 7 | 9)));
        assert!(batch.draws[0]
            .probs
            .iter()
            .all(|&q| (q - 1.0 / 6.0).abs() < 1e-12));
    }

    #[test]
    fn loguniform_pmf_sums_to_one_and_is_decreasing() {
        let s = LogUniformSampler::new(1000);
        let total: f64 = (0..1000).map(|i| s.probability(&[], i)).sum();
        assert!((total - 1.0).abs() < 1e-9, "Σq = {total}");
        assert!(s.probability(&[], 0) > s.probability(&[], 999));
    }

    #[test]
    fn loguniform_empirical_matches_pmf() {
        let s = LogUniformSampler::new(50);
        let mut rng = Rng::seeded(112);
        chi2_check(&s, &[], 200_000, &mut rng, 5.0);
    }

    #[test]
    fn alias_sampler_matches_weights() {
        let w = vec![1.0, 5.0, 0.5, 2.0, 1.5];
        let s = AliasSampler::new(&w);
        let mut rng = Rng::seeded(113);
        chi2_check(&s, &[], 100_000, &mut rng, 5.0);
    }

    #[test]
    fn exact_softmax_matches_brute_force() {
        let mut rng = Rng::seeded(114);
        let n = 30;
        let d = 8;
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let s = ExactSoftmaxSampler::new(&classes, 3.0);
        let h = unit_vector(&mut rng, d);
        // Direct softmax check.
        // Match the sampler's f32 multiply-then-cast order exactly.
        let logits: Vec<f64> = (0..n)
            .map(|i| (3.0f32 * dot(&h, classes.row(i))) as f64)
            .collect();
        let p = crate::linalg::softmax(&logits);
        for i in 0..n {
            assert!((s.probability(&h, i) - p[i]).abs() < 1e-9);
        }
        chi2_check(&s, &h, 100_000, &mut rng, 5.0);
    }

    #[test]
    fn exact_softmax_update_changes_pmf() {
        let mut rng = Rng::seeded(115);
        let classes = Matrix::randn(&mut rng, 10, 4).l2_normalized_rows();
        let mut s = ExactSoftmaxSampler::new(&classes, 5.0);
        let h = unit_vector(&mut rng, 4);
        let before = s.probability(&h, 2);
        s.update_class(2, &h); // align class 2 with h
        assert!(s.probability(&h, 2) > before);
    }

    #[test]
    fn gumbel_returns_distinct_classes() {
        let mut rng = Rng::seeded(116);
        let classes = Matrix::randn(&mut rng, 40, 6).l2_normalized_rows();
        let s = GumbelTopKSampler::new(&classes, 4.0);
        let h = unit_vector(&mut rng, 6);
        let draw = s.sample(&h, 15, &mut rng);
        assert_eq!(draw.len(), 15);
        let set: std::collections::HashSet<_> = draw.ids.iter().collect();
        assert_eq!(set.len(), 15, "gumbel-top-k must be distinct");
    }

    #[test]
    fn gumbel_favors_high_logit_classes() {
        let mut rng = Rng::seeded(117);
        let d = 6;
        let mut classes = Matrix::randn(&mut rng, 20, d).l2_normalized_rows();
        let h = unit_vector(&mut rng, d);
        classes.row_mut(5).copy_from_slice(&h); // class 5 = argmax logit
        let s = GumbelTopKSampler::new(&classes, 10.0);
        let mut hits = 0;
        let trials = 300;
        for _ in 0..trials {
            let draw = s.sample(&h, 3, &mut rng);
            if draw.ids.contains(&5) {
                hits += 1;
            }
        }
        assert!(
            hits > trials * 8 / 10,
            "top class included only {hits}/{trials} times"
        );
    }

    #[test]
    fn exact_softmax_batch_matches_conditioned_pmf() {
        let mut rng = Rng::seeded(119);
        let n = 25;
        let d = 6;
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let s = ExactSoftmaxSampler::new(&classes, 4.0);
        let bsz = 3;
        let mut h = Matrix::zeros(bsz, d);
        for b in 0..bsz {
            let v = unit_vector(&mut rng, d);
            h.row_mut(b).copy_from_slice(&v);
        }
        let targets = [0u32, 7, 24];
        let batch = s.sample_batch(&h, &targets, 60, &mut rng);
        for (b, draw) in batch.draws.iter().enumerate() {
            let t = targets[b] as usize;
            let q_t = s.probability(h.row(b), t);
            assert_eq!(draw.len(), 60);
            for (&id, &q) in draw.ids.iter().zip(&draw.probs) {
                assert_ne!(id as usize, t);
                let want = s.probability(h.row(b), id as usize) / (1.0 - q_t);
                assert!(
                    (q - want).abs() < 1e-9,
                    "example {b} id {id}: {q} vs {want}"
                );
            }
        }
    }

    #[test]
    fn uniform_batch_is_uniform_excluding_target() {
        let s = UniformSampler::new(8);
        let mut rng = Rng::seeded(125);
        let h = Matrix::zeros(2, 3);
        let batch = s.sample_batch(&h, &[1, 6], 2000, &mut rng);
        for (b, &t) in [1u32, 6].iter().enumerate() {
            let draw = &batch.draws[b];
            assert!(draw.ids.iter().all(|&i| i != t && i < 8));
            assert!(draw
                .probs
                .iter()
                .all(|&q| (q - 1.0 / 7.0).abs() < 1e-12));
            // Every non-target class shows up in 2000 draws.
            let mut seen = [false; 8];
            for &i in &draw.ids {
                seen[i as usize] = true;
            }
            assert_eq!(
                seen.iter().filter(|&&x| x).count(),
                7,
                "coverage for target {t}"
            );
        }
    }

    #[test]
    fn sample_negatives_renormalizes() {
        // For uniform over n classes excluding t, q' must be 1/(n-1)·…
        // — exactly q/(1-q_t).
        let s = UniformSampler::new(10);
        let mut rng = Rng::seeded(118);
        let draw = s.sample_negatives(&[], 3, 1000, &mut rng);
        assert!(draw.ids.iter().all(|&i| i != 3));
        for &q in &draw.probs {
            assert!((q - (0.1 / 0.9)).abs() < 1e-12);
        }
    }
}
