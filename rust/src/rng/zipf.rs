//! Zipf-distributed sampler over `{0, ..., n-1}`:
//! `P(k) ∝ 1/(k+1)^s`. Precomputes the CDF once, samples by binary search
//! (O(log n)). Drives the class-frequency skew of the synthetic language
//! corpora (natural-language unigram frequencies are famously Zipfian).

use super::Rng;

#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
    s: f64,
}

impl Zipf {
    /// `n` outcomes with exponent `s` (s=1.0 is the classic Zipf law).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf: n must be positive");
        assert!(s >= 0.0 && s.is_finite(), "Zipf: bad exponent {s}");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf, s }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    pub fn exponent(&self) -> f64 {
        self.s
    }

    /// Probability of outcome `k`.
    pub fn probability(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }

    /// Draw one outcome in O(log n).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // partition_point returns the first index with cdf > u.
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }

    /// The full pmf (used to build alias tables / priors).
    pub fn pmf(&self) -> Vec<f64> {
        (0..self.len()).map(|k| self.probability(k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        let z = Zipf::new(1000, 1.0);
        let s: f64 = z.pmf().iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn head_heavier_than_tail() {
        let z = Zipf::new(100, 1.0);
        assert!(z.probability(0) > 10.0 * z.probability(99));
    }

    #[test]
    fn s_zero_is_uniform() {
        let z = Zipf::new(10, 0.0);
        for k in 0..10 {
            assert!((z.probability(k) - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn empirical_matches_pmf() {
        let z = Zipf::new(50, 1.2);
        let mut rng = Rng::seeded(11);
        let trials = 300_000;
        let mut counts = vec![0usize; 50];
        for _ in 0..trials {
            counts[z.sample(&mut rng)] += 1;
        }
        for k in 0..50 {
            let p = z.probability(k);
            let f = counts[k] as f64 / trials as f64;
            assert!(
                (f - p).abs() < 0.01 + 3.0 * (p / trials as f64).sqrt() * 10.0,
                "k={k}: {f} vs {p}"
            );
        }
    }

    #[test]
    fn sample_in_range() {
        let z = Zipf::new(7, 2.0);
        let mut rng = Rng::seeded(12);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 7);
        }
    }
}
