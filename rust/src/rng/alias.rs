//! Walker alias method: O(n) construction, O(1) sampling from a fixed
//! categorical distribution. Used for static sampling priors (log-uniform,
//! unigram) and inside the synthetic data generators.

use super::Rng;

/// Alias table over `n` outcomes.
#[derive(Clone, Debug)]
pub struct AliasTable {
    /// Acceptance probability for the "home" outcome of each bucket.
    prob: Vec<f64>,
    /// Alias outcome used when the home outcome is rejected.
    alias: Vec<u32>,
    /// The normalized pmf (kept for exact probability queries).
    pmf: Vec<f64>,
}

impl AliasTable {
    /// Build from unnormalized non-negative weights. Panics on empty input
    /// or zero/negative total mass.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "AliasTable: empty weights");
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "AliasTable: invalid total mass {total}"
        );
        assert!(
            weights.iter().all(|&w| w >= 0.0),
            "AliasTable: negative weight"
        );
        let pmf: Vec<f64> = weights.iter().map(|&w| w / total).collect();

        // Scaled probabilities; bucket i is "small" if scaled < 1.
        let mut scaled: Vec<f64> = pmf.iter().map(|&p| p * n as f64).collect();
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }

        let mut prob = vec![1.0f64; n];
        let mut alias = vec![0u32; n];
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s as usize] = scaled[s as usize];
            alias[s as usize] = l;
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers (numerical slack) get probability 1.
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
        }

        Self { prob, alias, pmf }
    }

    /// Number of outcomes.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }

    /// Exact probability of outcome `i` under the table's distribution.
    #[inline]
    pub fn probability(&self, i: usize) -> f64 {
        self.pmf[i]
    }

    /// Draw one outcome in O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.index(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    /// Draw `m` outcomes (with replacement).
    pub fn sample_many(&self, rng: &mut Rng, m: usize) -> Vec<usize> {
        (0..m).map(|_| self.sample(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(weights: &[f64], trials: usize, seed: u64) -> Vec<f64> {
        let table = AliasTable::new(weights);
        let mut rng = Rng::seeded(seed);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..trials {
            counts[table.sample(&mut rng)] += 1;
        }
        counts.iter().map(|&c| c as f64 / trials as f64).collect()
    }

    #[test]
    fn matches_pmf_uniformish() {
        let w = [1.0, 1.0, 1.0, 1.0];
        let freq = empirical(&w, 200_000, 1);
        for f in freq {
            assert!((f - 0.25).abs() < 0.006, "{f}");
        }
    }

    #[test]
    fn matches_pmf_skewed() {
        let w = [0.5, 10.0, 0.01, 3.0, 0.0, 1.0];
        let total: f64 = w.iter().sum();
        let freq = empirical(&w, 400_000, 2);
        for (i, f) in freq.iter().enumerate() {
            let p = w[i] / total;
            assert!((f - p).abs() < 0.01, "class {i}: {f} vs {p}");
        }
        // Zero-weight class never sampled.
        assert_eq!(freq[4], 0.0);
    }

    #[test]
    fn probability_query_is_normalized() {
        let w = [2.0, 3.0, 5.0];
        let t = AliasTable::new(&w);
        let s: f64 = (0..3).map(|i| t.probability(i)).sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!((t.probability(2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn single_outcome() {
        let t = AliasTable::new(&[42.0]);
        let mut rng = Rng::seeded(3);
        for _ in 0..100 {
            assert_eq!(t.sample(&mut rng), 0);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_mass() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_negative() {
        AliasTable::new(&[1.0, -0.5]);
    }
}
