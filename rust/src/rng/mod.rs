//! Deterministic pseudo-random number substrate.
//!
//! The public registry has no `rand` crate available offline, and
//! reproducibility of every experiment matters more than cryptographic
//! quality, so we implement a small, fast, well-tested stack from scratch:
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Rng`] — xoshiro256++ core with convenience samplers (uniform,
//!   gaussian via Box–Muller, exponential, categorical, permutation).
//! * [`Zipf`] — Zipf(s) sampler over `{0..n-1}` by inverse-CDF binary
//!   search; drives the synthetic language-model corpus.
//! * [`AliasTable`] — Walker alias method: O(n) build, O(1) sample; used
//!   for static sampling priors (log-uniform / unigram) and inside data
//!   generators.

mod alias;
mod zipf;

pub use alias::AliasTable;
pub use zipf::Zipf;

/// SplitMix64: tiny generator used to seed and split streams.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
///
/// Period 2²⁵⁶−1, passes BigCrush; `jump()` provides 2¹²⁸ non-overlapping
/// subsequences for parallel workers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Deterministically seed from a single u64 via SplitMix64 (the
    /// canonical xoshiro seeding procedure).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for worker threads / shards).
    /// Children are seeded from fresh SplitMix64 output so that sibling
    /// streams never share state with the parent.
    pub fn split(&mut self) -> Rng {
        Rng::seeded(self.next_u64() ^ 0xA02_BDBF7BB3_C0A7)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// 2¹²⁸-step jump: advances the stream as if `next_u64` were called
    /// 2¹²⁸ times. Used to carve non-overlapping worker streams.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180ec6d33cfd0aba,
            0xd5a61266f0c9392c,
            0xa9582618e03fc9aa,
            0x39abdc4529b1661c,
        ];
        let mut s0 = 0u64;
        let mut s1 = 0u64;
        let mut s2 = 0u64;
        let mut s3 = 0u64;
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s0 ^= self.s[0];
                    s1 ^= self.s[1];
                    s2 ^= self.s[2];
                    s3 ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = [s0, s1, s2, s3];
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in (0, 1] — never returns exactly zero; safe for `ln()`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        loop {
            let x = self.f64();
            if x > 0.0 {
                return x;
            }
        }
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire: multiply-shift with rejection on the low word.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (caches the spare value).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as f32.
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Exponential(1) variate.
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        -self.f64_open().ln()
    }

    /// Standard Gumbel variate (used by the Gumbel-top-k sampler).
    #[inline]
    pub fn gumbel(&mut self) -> f64 {
        -(-self.f64_open().ln()).ln()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from an unnormalized non-negative weight slice in
    /// O(n). For repeated sampling from static weights use [`AliasTable`].
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical: zero total mass");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm when k≪n,
    /// partial shuffle otherwise).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        if k * 4 >= n {
            let mut p = self.permutation(n);
            p.truncate(k);
            return p;
        }
        // Robert Floyd's sampling algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fill a slice with standard gaussians (f32).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::seeded(7);
        let mut c = a.split();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.below(17);
            assert!(k < 17);
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::seeded(2);
        let n = 10u64;
        let mut counts = [0usize; 10];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n) as usize] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(),
                "count {c} too far from {expect}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seeded(3);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sum2 += g * g;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn gumbel_mean_is_euler_gamma() {
        let mut r = Rng::seeded(4);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.gumbel()).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.02, "gumbel mean {mean}");
    }

    #[test]
    fn categorical_matches_weights() {
        let mut r = Rng::seeded(5);
        let w = [1.0, 2.0, 3.0, 4.0];
        let mut counts = [0usize; 4];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.categorical(&w)] += 1;
        }
        for i in 0..4 {
            let p = w[i] / 10.0;
            let freq = counts[i] as f64 / trials as f64;
            assert!((freq - p).abs() < 0.01, "class {i}: {freq} vs {p}");
        }
    }

    #[test]
    fn sample_distinct_is_distinct_and_in_range() {
        let mut r = Rng::seeded(6);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10), (1, 1), (1000, 1)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::seeded(8);
        let p = r.permutation(257);
        let mut q = p.clone();
        q.sort_unstable();
        assert_eq!(q, (0..257).collect::<Vec<_>>());
    }

    #[test]
    fn jump_decorrelates() {
        let mut a = Rng::seeded(9);
        let mut b = a.clone();
        b.jump();
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
