//! Plain-text table renderer for paper-style outputs, shared by all bench
//! harnesses so Table 1–3 / Figure 1–4 reproductions print the same rows
//! the paper reports.

/// A simple column-aligned text table.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "Table::row: wrong cell count"
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let c = &cells[i];
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(widths[i] - c.chars().count() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV render (for plotting outside).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Format helpers used across benches.
pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let exp = x.abs().log10().floor() as i32;
    if (-2..4).contains(&exp) {
        format!("{x:.4}")
    } else {
        format!("{:.1}e{}", x / 10f64.powi(exp), exp)
    }
}

pub fn fmt_ms(seconds: f64) -> String {
    format!("{:.2} ms", seconds * 1e3)
}

pub fn fmt_us(seconds: f64) -> String {
    format!("{:.1} µs", seconds * 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["method", "value"]);
        t.row_strs(&["rff", "1.0"]);
        t.row_strs(&["quadratic-long", "2"]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("| rff"));
        assert!(s.contains("| quadratic-long |"));
        // All table lines equal width.
        let lens: Vec<usize> = s
            .lines()
            .skip(1)
            .map(|l| l.chars().count())
            .collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["h,i", "say \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"h,i\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn sci_formatting() {
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(2.8e-3), "2.8e-3");
        assert_eq!(fmt_sci(5.5e-6), "5.5e-6");
        assert!(fmt_sci(1.5).starts_with("1.5"));
    }
}
