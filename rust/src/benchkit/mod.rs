//! Benchmark harness (criterion substitute, DESIGN.md §2).
//!
//! Features: warmup, adaptive iteration counts targeting a measurement
//! budget, mean / p50 / p95 / stddev over per-iteration samples, throughput
//! reporting, and a `black_box` to defeat constant folding. All bench
//! targets (`rust/benches/*.rs`, `harness = false`) print through this.

use std::hint;
use std::time::{Duration, Instant};

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// One benchmark's collected samples (seconds per iteration).
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub samples: Vec<f64>,
    pub iters_per_sample: u64,
}

impl Summary {
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|&x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.samples.len().max(2) - 1) as f64;
        var.sqrt()
    }

    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    pub fn quantile(&self, q: f64) -> f64 {
        let s = self.sorted();
        let idx = ((s.len() - 1) as f64 * q).round() as usize;
        s[idx]
    }

    pub fn min(&self) -> f64 {
        self.sorted()[0]
    }

    /// Render a one-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>12}  p50 {:>12}  p95 {:>12}  ±{:>9}  ({} samples × {} iters)",
            self.name,
            fmt_time(self.mean()),
            fmt_time(self.quantile(0.5)),
            fmt_time(self.quantile(0.95)),
            fmt_time(self.stddev()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

/// Format a duration in adaptive units.
pub fn fmt_time(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.1} ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{seconds:.3} s")
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Wall-clock budget for warmup.
    pub warmup: Duration,
    /// Wall-clock budget for measurement.
    pub budget: Duration,
    /// Number of samples to split the budget into.
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(1),
            samples: 20,
        }
    }
}

impl Bencher {
    /// Quick preset for heavy end-to-end benches (training runs).
    pub fn heavy() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            budget: Duration::from_millis(1),
            samples: 1,
        }
    }

    /// Measure `f`, calling it repeatedly. Each sample times a batch of
    /// iterations sized so that one batch ≈ budget/samples.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        // Warmup + estimate cost of one iteration.
        let mut iters_done: u64 = 0;
        let t0 = Instant::now();
        loop {
            black_box(f());
            iters_done += 1;
            if t0.elapsed() >= self.warmup && iters_done >= 3 {
                break;
            }
        }
        let per_iter = t0.elapsed().as_secs_f64() / iters_done as f64;
        let per_sample_budget =
            self.budget.as_secs_f64() / self.samples as f64;
        let iters_per_sample =
            ((per_sample_budget / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        Summary { name: name.to_string(), samples, iters_per_sample }
    }

    /// Measure once (for long-running end-to-end drivers where a single
    /// execution IS the experiment).
    pub fn run_once<T>(&self, name: &str, f: impl FnOnce() -> T) -> (T, Summary) {
        let t = Instant::now();
        let out = black_box(f());
        let dt = t.elapsed().as_secs_f64();
        (
            out,
            Summary { name: name.to_string(), samples: vec![dt], iters_per_sample: 1 },
        )
    }
}

/// Standard bench-binary entry header (so every bench output is labeled
/// and greppable in bench_output.txt).
pub fn bench_header(id: &str, description: &str) {
    println!();
    println!("=== {id} — {description} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(40),
            samples: 5,
        };
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = acc.wrapping_add(1);
            black_box(acc)
        });
        assert!(s.mean() > 0.0);
        assert_eq!(s.samples.len(), 5);
        assert!(s.quantile(0.5) <= s.quantile(0.95) + 1e-12);
    }

    #[test]
    fn run_once_returns_value() {
        let b = Bencher::default();
        let (v, s) = b.run_once("x", || 42);
        assert_eq!(v, 42);
        assert_eq!(s.samples.len(), 1);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }

    #[test]
    fn summary_stats_reasonable() {
        let s = Summary {
            name: "t".into(),
            samples: vec![1.0, 2.0, 3.0],
            iters_per_sample: 1,
        };
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert!((s.stddev() - 1.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
    }
}
