//! Evaluation metrics: perplexity (NLP experiments, Figures 1–4) and
//! precision@k (extreme classification, Table 3).

/// Perplexity from a mean cross-entropy (natural-log) loss.
pub fn perplexity(mean_loss: f64) -> f64 {
    mean_loss.exp()
}

/// PREC@k for one example: fraction of the top-k predictions that are in
/// the label set (the extreme-classification convention, paper §4.1).
pub fn precision_at_k(scores: &[f32], labels: &[u32], k: usize) -> f64 {
    assert!(k >= 1);
    let k = k.min(scores.len());
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    let labelset: std::collections::HashSet<u32> =
        labels.iter().copied().collect();
    let hits = idx.iter().filter(|i| labelset.contains(i)).count();
    hits as f64 / k as f64
}

/// Batched PREC@k: `scores` is `batch × n` row-major; `labels[i]` the
/// label set of example i. Returns the mean over examples.
pub fn batch_precision_at_k(
    scores: &[f32],
    n: usize,
    labels: &[Vec<u32>],
    k: usize,
) -> f64 {
    assert_eq!(scores.len(), n * labels.len());
    let mut acc = 0.0;
    for (i, ls) in labels.iter().enumerate() {
        acc += precision_at_k(&scores[i * n..(i + 1) * n], ls, k);
    }
    acc / labels.len() as f64
}

/// Top-k indices by score, descending (ties broken arbitrarily).
pub fn top_k(scores: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..scores.len() as u32).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx.sort_by(|&a, &b| {
        scores[b as usize]
            .partial_cmp(&scores[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perplexity_of_uniform() {
        // Uniform over 100 classes → loss = ln 100 → ppl = 100.
        assert!((perplexity((100f64).ln()) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn prec_at_k_basics() {
        let scores = [0.1f32, 0.9, 0.5, 0.3];
        // top-1 = class 1.
        assert_eq!(precision_at_k(&scores, &[1], 1), 1.0);
        assert_eq!(precision_at_k(&scores, &[0], 1), 0.0);
        // top-2 = {1, 2}; labels {2, 3} → 1 hit of 2.
        assert_eq!(precision_at_k(&scores, &[2, 3], 2), 0.5);
    }

    #[test]
    fn prec_k_clamps_to_n() {
        let scores = [0.5f32, 0.4];
        assert_eq!(precision_at_k(&scores, &[0, 1], 10), 1.0);
    }

    #[test]
    fn batch_prec_mean() {
        let n = 3;
        // ex0 scores favor class 0; ex1 favor class 2.
        let scores = vec![0.9f32, 0.1, 0.0, 0.0, 0.1, 0.9];
        let labels = vec![vec![0u32], vec![0u32]];
        let p = batch_precision_at_k(&scores, n, &labels, 1);
        assert!((p - 0.5).abs() < 1e-12);
    }

    #[test]
    fn top_k_is_sorted_desc() {
        let scores = [0.2f32, 0.9, 0.5, 0.7];
        assert_eq!(top_k(&scores, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&scores, 0), Vec::<u32>::new());
    }
}
