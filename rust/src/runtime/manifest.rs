//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`). Example document:
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": {
//!     "ptb_train_sampled": {
//!       "file": "ptb_train_sampled.hlo.txt",
//!       "inputs":  [{"name": "ctx_emb", "dtype": "f32", "shape": [32, 10, 100]}],
//!       "outputs": [{"name": "loss", "dtype": "f32", "shape": []}],
//!       "meta": {"config": "ptb", "tau": 11.11}
//!     }
//!   }
//! }
//! ```

use crate::json::{self, Json};
use std::collections::BTreeMap;

/// Shape + dtype of one input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: &'static str,
    pub shape: Vec<usize>,
}

/// One entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    /// Free-form metadata (the generating config, τ, etc.).
    pub meta: Json,
}

impl ArtifactMeta {
    /// Look up a numeric metadata field (e.g. `tau`).
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|j| j.as_f64())
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }

    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = json::parse(text).map_err(|e| e.to_string())?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_object())
            .ok_or("manifest: missing 'artifacts' object")?;
        let mut artifacts = BTreeMap::new();
        for (name, body) in arts {
            let file = body
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| format!("artifact '{name}': missing file"))?
                .to_string();
            let inputs = parse_tensors(body.get("inputs"), name, "inputs")?;
            let outputs = parse_tensors(body.get("outputs"), name, "outputs")?;
            let meta =
                body.get("meta").cloned().unwrap_or(Json::Obj(Default::default()));
            artifacts.insert(
                name.clone(),
                ArtifactMeta { name: name.clone(), file, inputs, outputs, meta },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.values()
    }
}

fn parse_tensors(
    j: Option<&Json>,
    artifact: &str,
    field: &str,
) -> Result<Vec<TensorMeta>, String> {
    let arr = j
        .and_then(|x| x.as_array())
        .ok_or_else(|| format!("artifact '{artifact}': missing {field}"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let name = t
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or(&format!("{field}{i}"))
            .to_string();
        let dtype = match t.get("dtype").and_then(|d| d.as_str()) {
            Some("f32") => "f32",
            Some("i32") => "i32",
            other => {
                return Err(format!(
                    "artifact '{artifact}' {field}[{i}]: bad dtype {other:?}"
                ))
            }
        };
        let shape = t
            .get("shape")
            .and_then(|s| s.as_array())
            .ok_or_else(|| {
                format!("artifact '{artifact}' {field}[{i}]: missing shape")
            })?
            .iter()
            .map(|d| {
                d.as_usize().ok_or_else(|| {
                    format!("artifact '{artifact}' {field}[{i}]: bad dim")
                })
            })
            .collect::<Result<Vec<_>, _>>()?;
        out.push(TensorMeta { name, dtype, shape });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": {
            "demo": {
                "file": "demo.hlo.txt",
                "inputs": [
                    {"name": "x", "dtype": "f32", "shape": [2, 3]},
                    {"name": "ids", "dtype": "i32", "shape": [4]}
                ],
                "outputs": [{"name": "loss", "dtype": "f32", "shape": []}],
                "meta": {"tau": 4.0, "config": "tiny"}
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("demo").unwrap();
        assert_eq!(a.file, "demo.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[1].dtype, "i32");
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.meta_f64("tau"), Some(4.0));
        assert_eq!(a.input_index("ids"), Some(1));
        assert_eq!(a.input_index("nope"), None);
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"i32\"", "\"f16\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_artifacts_key() {
        assert!(Manifest::parse("{}").is_err());
    }
}
