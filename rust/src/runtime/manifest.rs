//! Typed view of `artifacts/manifest.json` (written by
//! `python/compile/aot.py`, and since the durability work also *by*
//! this crate when registering sampler snapshots). Example document:
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": {
//!     "ptb_train_sampled": {
//!       "file": "ptb_train_sampled.hlo.txt",
//!       "inputs":  [{"name": "ctx_emb", "dtype": "f32", "shape": [32, 10, 100]}],
//!       "outputs": [{"name": "loss", "dtype": "f32", "shape": []}],
//!       "meta": {"config": "ptb", "tau": 11.11}
//!     }
//!   },
//!   "snapshots": {
//!     "serve_main": {
//!       "file": "serve_main.rfsnap",
//!       "kind": "sharded",
//!       "epoch": 1812,
//!       "n_classes": 1000000,
//!       "live_classes": 998731,
//!       "bytes": 408772113,
//!       "checksum": "0x1f3a9c0d5e7b2460"
//!     }
//!   }
//! }
//! ```
//!
//! The `snapshots` section is optional (AOT manifests predate it) and
//! its `checksum` is the snapshot file's FNV-1a trailer rendered as a
//! hex string — `Json::Num` is f64-backed, so a u64 cannot survive as
//! a JSON number.

use crate::json::{self, Json};
use std::collections::BTreeMap;

/// Shape + dtype of one input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: &'static str,
    pub shape: Vec<usize>,
}

/// One entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    /// Free-form metadata (the generating config, τ, etc.).
    pub meta: Json,
}

impl ArtifactMeta {
    /// Look up a numeric metadata field (e.g. `tau`).
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|j| j.as_f64())
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|j| j.as_usize())
    }

    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|t| t.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|t| t.name == name)
    }
}

/// One registered sampler snapshot (see [`crate::snapshot`]). The
/// `checksum` mirrors the snapshot file's FNV-1a trailer so a stale
/// manifest ↔ file pair is caught before decode even starts.
#[derive(Clone, Debug, PartialEq)]
pub struct SnapshotMeta {
    pub name: String,
    pub file: String,
    /// Sampler kind spelling (`uniform`/`kernel`/`sharded`/`bucket`).
    pub kind: String,
    /// Serving epoch at capture — the replication-log replay point.
    pub epoch: u64,
    pub n_classes: usize,
    pub live_classes: usize,
    pub bytes: usize,
    pub checksum: u64,
}

impl SnapshotMeta {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("file", Json::Str(self.file.clone())),
            ("kind", Json::Str(self.kind.clone())),
            ("epoch", Json::Num(self.epoch as f64)),
            ("n_classes", Json::Num(self.n_classes as f64)),
            ("live_classes", Json::Num(self.live_classes as f64)),
            ("bytes", Json::Num(self.bytes as f64)),
            ("checksum", Json::Str(format!("{:#018x}", self.checksum))),
        ])
    }

    fn parse(name: &str, body: &Json) -> Result<SnapshotMeta, String> {
        let field = |key: &str| {
            body.get(key)
                .ok_or_else(|| format!("snapshot '{name}': missing {key}"))
        };
        let checksum_text = field("checksum")?
            .as_str()
            .ok_or_else(|| format!("snapshot '{name}': checksum not a string"))?;
        let checksum = u64::from_str_radix(
            checksum_text.trim_start_matches("0x"),
            16,
        )
        .map_err(|_| format!("snapshot '{name}': bad checksum hex"))?;
        let num = |key: &str| -> Result<usize, String> {
            field(key)?
                .as_usize()
                .ok_or_else(|| format!("snapshot '{name}': bad {key}"))
        };
        Ok(SnapshotMeta {
            name: name.to_string(),
            file: field("file")?
                .as_str()
                .ok_or_else(|| format!("snapshot '{name}': bad file"))?
                .to_string(),
            kind: field("kind")?
                .as_str()
                .ok_or_else(|| format!("snapshot '{name}': bad kind"))?
                .to_string(),
            epoch: num("epoch")? as u64,
            n_classes: num("n_classes")?,
            live_classes: num("live_classes")?,
            bytes: num("bytes")?,
            checksum,
        })
    }
}

/// The whole manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactMeta>,
    snapshots: BTreeMap<String, SnapshotMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = json::parse(text).map_err(|e| e.to_string())?;
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_object())
            .ok_or("manifest: missing 'artifacts' object")?;
        let mut artifacts = BTreeMap::new();
        for (name, body) in arts {
            let file = body
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| format!("artifact '{name}': missing file"))?
                .to_string();
            let inputs = parse_tensors(body.get("inputs"), name, "inputs")?;
            let outputs = parse_tensors(body.get("outputs"), name, "outputs")?;
            let meta =
                body.get("meta").cloned().unwrap_or(Json::Obj(Default::default()));
            artifacts.insert(
                name.clone(),
                ArtifactMeta { name: name.clone(), file, inputs, outputs, meta },
            );
        }
        let mut snapshots = BTreeMap::new();
        if let Some(snaps) = j.get("snapshots").and_then(|s| s.as_object()) {
            for (name, body) in snaps {
                snapshots
                    .insert(name.clone(), SnapshotMeta::parse(name, body)?);
            }
        }
        Ok(Manifest { artifacts, snapshots })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.values()
    }

    /// Look up a registered sampler snapshot by name.
    pub fn snapshot(&self, name: &str) -> Option<&SnapshotMeta> {
        self.snapshots.get(name)
    }

    pub fn snapshots(&self) -> impl Iterator<Item = &SnapshotMeta> {
        self.snapshots.values()
    }

    /// Register (or replace) a snapshot entry. Call
    /// [`Manifest::to_json_string`] afterwards to persist.
    pub fn insert_snapshot(&mut self, meta: SnapshotMeta) {
        self.snapshots.insert(meta.name.clone(), meta);
    }

    /// Render the manifest back to JSON. Round-trips everything
    /// `parse` reads (artifacts keep their free-form `meta`), so
    /// registering a snapshot never loses AOT entries.
    pub fn to_json_string(&self) -> String {
        let artifacts: BTreeMap<String, Json> = self
            .artifacts
            .iter()
            .map(|(name, a)| {
                (
                    name.clone(),
                    Json::obj(vec![
                        ("file", Json::Str(a.file.clone())),
                        ("inputs", tensors_to_json(&a.inputs)),
                        ("outputs", tensors_to_json(&a.outputs)),
                        ("meta", a.meta.clone()),
                    ]),
                )
            })
            .collect();
        let snapshots: BTreeMap<String, Json> = self
            .snapshots
            .iter()
            .map(|(name, s)| (name.clone(), s.to_json()))
            .collect();
        let doc = Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("artifacts", Json::Obj(artifacts)),
            ("snapshots", Json::Obj(snapshots)),
        ]);
        json::to_string_pretty(&doc)
    }
}

fn tensors_to_json(tensors: &[TensorMeta]) -> Json {
    Json::Arr(
        tensors
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::Str(t.name.clone())),
                    ("dtype", Json::Str(t.dtype.to_string())),
                    ("shape", Json::arr_usize(&t.shape)),
                ])
            })
            .collect(),
    )
}

fn parse_tensors(
    j: Option<&Json>,
    artifact: &str,
    field: &str,
) -> Result<Vec<TensorMeta>, String> {
    let arr = j
        .and_then(|x| x.as_array())
        .ok_or_else(|| format!("artifact '{artifact}': missing {field}"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let name = t
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or(&format!("{field}{i}"))
            .to_string();
        let dtype = match t.get("dtype").and_then(|d| d.as_str()) {
            Some("f32") => "f32",
            Some("i32") => "i32",
            other => {
                return Err(format!(
                    "artifact '{artifact}' {field}[{i}]: bad dtype {other:?}"
                ))
            }
        };
        let shape = t
            .get("shape")
            .and_then(|s| s.as_array())
            .ok_or_else(|| {
                format!("artifact '{artifact}' {field}[{i}]: missing shape")
            })?
            .iter()
            .map(|d| {
                d.as_usize().ok_or_else(|| {
                    format!("artifact '{artifact}' {field}[{i}]: bad dim")
                })
            })
            .collect::<Result<Vec<_>, _>>()?;
        out.push(TensorMeta { name, dtype, shape });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": {
            "demo": {
                "file": "demo.hlo.txt",
                "inputs": [
                    {"name": "x", "dtype": "f32", "shape": [2, 3]},
                    {"name": "ids", "dtype": "i32", "shape": [4]}
                ],
                "outputs": [{"name": "loss", "dtype": "f32", "shape": []}],
                "meta": {"tau": 4.0, "config": "tiny"}
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("demo").unwrap();
        assert_eq!(a.file, "demo.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[1].dtype, "i32");
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.meta_f64("tau"), Some(4.0));
        assert_eq!(a.input_index("ids"), Some(1));
        assert_eq!(a.input_index("nope"), None);
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = SAMPLE.replace("\"i32\"", "\"f16\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_artifacts_key() {
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn snapshot_section_round_trips_with_artifacts_intact() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        m.insert_snapshot(SnapshotMeta {
            name: "serve_main".to_string(),
            file: "serve_main.rfsnap".to_string(),
            kind: "sharded".to_string(),
            epoch: 1812,
            n_classes: 1_000_000,
            live_classes: 998_731,
            bytes: 4096,
            checksum: 0xdead_beef_cafe_f00d,
        });
        let text = m.to_json_string();
        let back = Manifest::parse(&text).unwrap();
        // AOT artifact survives re-rendering, field for field.
        assert_eq!(back.get("demo"), m.get("demo"));
        let s = back.snapshot("serve_main").unwrap();
        assert_eq!(s.checksum, 0xdead_beef_cafe_f00d);
        assert_eq!(s.epoch, 1812);
        assert_eq!(s.kind, "sharded");
        assert!(back.snapshot("nope").is_none());
        // Manifests without the section parse to an empty map.
        assert_eq!(Manifest::parse(SAMPLE).unwrap().snapshots().count(), 0);
    }
}
