//! Native fused train-step kernels (the default `train.backend`).
//!
//! One-pass f32 implementations of the three entry points the trainers
//! need — encode (LSTM forward), the fused sampled-softmax train step
//! (paper eq. 5–6), and the full-softmax eval/train step — built on the
//! `linalg::simd` microkernels (`matmul_nt_into`, `dot`, `axpy`) with
//! serving-style reusable scratch and fan-out over [`exec::serve_pool`].
//!
//! Design rules (mirrors the serving hot path):
//!
//! * **No `bsz×m` intermediates.** Logits for `[target | negatives]` are
//!   produced tile-by-tile ([`TILE`] classes at a time); the `−log(m·q)`
//!   correction and the accidental-hit mask are applied in-register; a
//!   streaming (online) logsumexp carries `(max, Σexp)` per row in f64,
//!   and the backward pass re-computes each tile instead of storing it —
//!   the flash-attention recompute trick, a win because the tile gemm is
//!   cheaper than hauling `bsz×m` floats through memory twice.
//! * **Zero steady-state allocations.** Every buffer lives in the kernel
//!   struct and is re-`ensure`d per step; a growth counter records any
//!   capacity growth so trainers can assert the step loop is
//!   allocation-flat after warmup (the small per-wave job boxes and
//!   range vectors are control-plane, not tracked).
//! * **Exact row partition.** A batch is split into contiguous row
//!   chunks, one pool job per chunk, each owning its rows' outputs;
//!   cross-row reductions (negative-class grads, dense weight grads) go
//!   through per-worker partial buffers summed after the wave, so no
//!   atomics and a deterministic summation order.
//!
//! Correctness is anchored to the f64 oracle in [`crate::softmax`] and
//! finite differences against f64 references (see the tests below), and
//! the unfused-but-equivalent [`composed`] pipeline doubles as both the
//! benchmark baseline for `table2_walltime --smoke` and an independent
//! implementation to diff against.

use crate::exec;
use crate::linalg::simd;
use crate::linalg::Matrix;

/// Normalization clamp: `x̂ = x / max(‖x‖, ε)` — the `tf.clip` semantics
/// of `model.py`, *not* [`crate::linalg::l2_normalize`]'s leave-zero
/// behavior. The backward for it is [`l2norm_bwd_inplace`].
pub const NORM_EPS: f32 = 1e-6;

/// Classes per logit tile: big enough that the `rb×TILE` gemm amortizes
/// dispatch, small enough that a tile of logits stays in L1.
const TILE: usize = 64;

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// `x ← x / max(‖x‖, ε)`, returning the raw norm for the backward.
pub fn l2_normalize_eps(x: &mut [f32]) -> f32 {
    let norm = simd::dot(x, x).sqrt();
    let inv = 1.0 / norm.max(NORM_EPS);
    for v in x.iter_mut() {
        *v *= inv;
    }
    norm
}

/// Backward of [`l2_normalize_eps`] through `y = x / max(‖x‖, ε)`:
/// given the *normalized* `y`, the raw `norm`, and `dy` in place,
/// produces `dx = (dy − y·(y·dy)) / norm` (or `dy/ε` in the clamped
/// regime, where the map is linear).
pub fn l2norm_bwd_inplace(y: &[f32], dy: &mut [f32], norm: f32) {
    if norm > NORM_EPS {
        let proj = simd::dot(y, dy);
        let inv = 1.0 / norm;
        for (dv, &yv) in dy.iter_mut().zip(y) {
            *dv = (*dv - yv * proj) * inv;
        }
    } else {
        let inv = 1.0 / NORM_EPS;
        for dv in dy.iter_mut() {
            *dv *= inv;
        }
    }
}

/// Contiguous row partition of `0..n` into at most `workers` non-empty
/// chunks (may return fewer than `workers` chunks for small `n`).
fn chunk_ranges(n: usize, workers: usize) -> Vec<(usize, usize)> {
    let w = workers.min(n).max(1);
    let per = n.div_ceil(w);
    let mut out = Vec::with_capacity(w);
    let mut s = 0;
    while s < n {
        let e = (s + per).min(n);
        out.push((s, e));
        s = e;
    }
    out
}

/// Split `data` (row width `width`) into per-chunk `&mut` blocks
/// matching `ranges` (which must partition a prefix of the rows in
/// order). The chunks are disjoint, so each pool job can own one.
fn split_chunks<'a, T>(
    mut data: &'a mut [T],
    width: usize,
    ranges: &[(usize, usize)],
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(ranges.len());
    let mut consumed = 0;
    for &(s, e) in ranges {
        debug_assert_eq!(s, consumed, "split_chunks: ranges must be dense");
        let (head, tail) = data.split_at_mut((e - s) * width);
        out.push(head);
        data = tail;
        consumed = e;
    }
    out
}

/// Size `buf` to exactly `len` elements, counting a capacity growth.
/// Contents are unspecified (callers must fully overwrite).
fn ensure_len<T: Copy + Default>(
    buf: &mut Vec<T>,
    len: usize,
    growths: &mut u64,
) {
    if buf.len() == len {
        return;
    }
    if buf.capacity() < len {
        *growths += 1;
    }
    buf.resize(len, T::default());
}

/// Size `buf` to exactly `len` zeroed elements, counting growth.
fn ensure_zeroed<T: Copy + Default>(
    buf: &mut Vec<T>,
    len: usize,
    growths: &mut u64,
) {
    if buf.capacity() < len {
        *growths += 1;
    }
    buf.clear();
    buf.resize(len, T::default());
}

/// `dst ← srcᵀ` for row-major `src` (`rows × cols`), reusing `dst`.
fn transpose_into(
    src: &[f32],
    rows: usize,
    cols: usize,
    dst: &mut Vec<f32>,
    growths: &mut u64,
) {
    assert_eq!(src.len(), rows * cols, "transpose_into: shape");
    ensure_len(dst, rows * cols, growths);
    for r in 0..rows {
        for c in 0..cols {
            dst[c * rows + r] = src[r * cols + c];
        }
    }
}

/// Gather `ids` rows of a flat `rows × dim` table into reusable scratch.
/// Returns `true` when the scratch had to grow (callers count it).
pub fn gather_rows_into(
    table: &[f32],
    dim: usize,
    ids: &[u32],
    out: &mut Vec<f32>,
) -> bool {
    let grew = out.capacity() < ids.len() * dim;
    out.clear();
    for &id in ids {
        let s = id as usize * dim;
        out.extend_from_slice(&table[s..s + dim]);
    }
    grew
}

// ---------------------------------------------------------------------
// Fused sampled-softmax loss + gradients
// ---------------------------------------------------------------------

/// The fused sampled-softmax loss/grad kernel (paper eq. 5–6): one pass
/// over `[target | shared negatives]` per batch row producing the mean
/// loss and gradients w.r.t. the **raw** (pre-normalization) query,
/// target-row, and negative-row embeddings.
///
/// Forward math per row `r` (matching the retired HLO artifact):
/// `q̂ = q/max(‖q‖,ε)`, `t̂`, `ĉ_j` likewise; `o_t = τ·q̂·t̂`;
/// `o_j = τ·q̂·ĉ_j − log(m·q_j)` (the `adjust` input *is*
/// `log(m·q_j)`); masked (accidental-hit) columns drop out of the sum;
/// `L_r = logsumexp([o_t, o_*]) − o_t`; loss is the batch mean. Under
/// `absolute` (the Quadratic baseline's §4.1 pairing) the softmax runs
/// over `|o|`.
///
/// Call [`FusedLoss::run`]; read `d_q` / `d_tgt` / `d_neg` after.
/// Queries, target rows and negative rows are normalized **in place**.
pub struct FusedLoss {
    workers: usize,
    q_norms: Vec<f32>,
    t_norms: Vec<f32>,
    n_norms: Vec<f32>,
    row_max: Vec<f64>,
    row_sum: Vec<f64>,
    lse: Vec<f64>,
    tlogit: Vec<f64>,
    loss_part: Vec<f64>,
    tile: Vec<f32>,
    chat_part: Vec<f32>,
    /// `∂L/∂q` (raw query rows), `bsz × d` row-major.
    pub d_q: Vec<f32>,
    /// `∂L/∂target_row`, `bsz × d` row-major.
    pub d_tgt: Vec<f32>,
    /// `∂L/∂neg_row`, `m × d` row-major (shared across the batch).
    pub d_neg: Vec<f32>,
    growths: u64,
}

impl FusedLoss {
    pub fn new(workers: usize) -> Self {
        FusedLoss {
            workers: workers.max(1),
            q_norms: Vec::new(),
            t_norms: Vec::new(),
            n_norms: Vec::new(),
            row_max: Vec::new(),
            row_sum: Vec::new(),
            lse: Vec::new(),
            tlogit: Vec::new(),
            loss_part: Vec::new(),
            tile: Vec::new(),
            chat_part: Vec::new(),
            d_q: Vec::new(),
            d_tgt: Vec::new(),
            d_neg: Vec::new(),
            growths: 0,
        }
    }

    /// Scratch-capacity growth events since construction (flat after
    /// warmup ⇒ the step loop is allocation-free for these buffers).
    pub fn growths(&self) -> u64 {
        self.growths
    }

    /// Run the fused step. `q` is `bsz × d` (normalized in place), `tgt`
    /// is `bsz·d` gathered target rows, `neg` is `m·d` gathered negative
    /// rows (both normalized in place), `adjust[j] = log(m·q_j)`, `mask`
    /// is `bsz × m` with 0 marking accidental hits. Returns mean loss.
    pub fn run(
        &mut self,
        q: &mut Matrix,
        tgt: &mut [f32],
        neg: &mut [f32],
        adjust: &[f32],
        mask: &[f32],
        tau: f32,
        absolute: bool,
    ) -> f32 {
        let b = q.rows();
        let d = q.cols();
        let m = adjust.len();
        assert!(b > 0 && d > 0 && m > 0, "FusedLoss: empty inputs");
        assert_eq!(tgt.len(), b * d, "FusedLoss: tgt shape");
        assert_eq!(neg.len(), m * d, "FusedLoss: neg shape");
        assert_eq!(mask.len(), b * m, "FusedLoss: mask shape");

        let pool = exec::serve_pool();
        let wb = self.workers.min(pool.size().max(1));
        let rq = chunk_ranges(b, wb);
        let rn = chunk_ranges(m, wb);
        let nq = rq.len();
        let rb_max = rq.iter().map(|&(s, e)| e - s).max().unwrap();
        let tw = TILE.min(m);

        ensure_zeroed(&mut self.d_q, b * d, &mut self.growths);
        ensure_zeroed(&mut self.d_tgt, b * d, &mut self.growths);
        ensure_zeroed(&mut self.d_neg, m * d, &mut self.growths);
        ensure_len(&mut self.q_norms, b, &mut self.growths);
        ensure_len(&mut self.t_norms, b, &mut self.growths);
        ensure_len(&mut self.n_norms, m, &mut self.growths);
        ensure_len(&mut self.row_max, b, &mut self.growths);
        ensure_len(&mut self.row_sum, b, &mut self.growths);
        ensure_len(&mut self.lse, b, &mut self.growths);
        ensure_len(&mut self.tlogit, b, &mut self.growths);
        ensure_len(&mut self.tile, nq * rb_max * tw, &mut self.growths);
        ensure_zeroed(&mut self.chat_part, nq * m * d, &mut self.growths);
        ensure_zeroed(&mut self.loss_part, nq, &mut self.growths);

        // Wave 1: normalize query / target / negative rows, saving norms.
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(2 * rq.len() + rn.len());
            let q_chunks = split_chunks(q.data_mut(), d, &rq);
            let qn_chunks = split_chunks(&mut self.q_norms, 1, &rq);
            for (rows, norms) in q_chunks.into_iter().zip(qn_chunks) {
                jobs.push(Box::new(move || {
                    for (i, nrm) in norms.iter_mut().enumerate() {
                        *nrm = l2_normalize_eps(&mut rows[i * d..(i + 1) * d]);
                    }
                }));
            }
            let t_chunks = split_chunks(&mut tgt[..], d, &rq);
            let tn_chunks = split_chunks(&mut self.t_norms, 1, &rq);
            for (rows, norms) in t_chunks.into_iter().zip(tn_chunks) {
                jobs.push(Box::new(move || {
                    for (i, nrm) in norms.iter_mut().enumerate() {
                        *nrm = l2_normalize_eps(&mut rows[i * d..(i + 1) * d]);
                    }
                }));
            }
            let c_chunks = split_chunks(&mut neg[..], d, &rn);
            let cn_chunks = split_chunks(&mut self.n_norms, 1, &rn);
            for (rows, norms) in c_chunks.into_iter().zip(cn_chunks) {
                jobs.push(Box::new(move || {
                    for (i, nrm) in norms.iter_mut().enumerate() {
                        *nrm = l2_normalize_eps(&mut rows[i * d..(i + 1) * d]);
                    }
                }));
            }
            pool.run_wave(jobs);
        }

        // Wave 2: per row-chunk fused forward + backward (each job owns
        // its d_q/d_tgt rows; negative grads go to per-worker partials).
        {
            let qd: &[f32] = q.data();
            let tg: &[f32] = tgt;
            let ng: &[f32] = neg;
            let q_norms = &self.q_norms;
            let t_norms = &self.t_norms;
            let mut dq_it = split_chunks(&mut self.d_q, d, &rq).into_iter();
            let mut dt_it = split_chunks(&mut self.d_tgt, d, &rq).into_iter();
            let mut rm_it = split_chunks(&mut self.row_max, 1, &rq).into_iter();
            let mut rs_it = split_chunks(&mut self.row_sum, 1, &rq).into_iter();
            let mut ls_it = split_chunks(&mut self.lse, 1, &rq).into_iter();
            let mut tl_it = split_chunks(&mut self.tlogit, 1, &rq).into_iter();
            let mut tile_it = self.tile.chunks_mut(rb_max * tw);
            let mut chat_it = self.chat_part.chunks_mut(m * d);
            let mut loss_it = self.loss_part.iter_mut();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(rq.len());
            for &(s, e) in &rq {
                let dq = dq_it.next().unwrap();
                let dt = dt_it.next().unwrap();
                let rm = rm_it.next().unwrap();
                let rs = rs_it.next().unwrap();
                let ls = ls_it.next().unwrap();
                let tl = tl_it.next().unwrap();
                let tile = tile_it.next().unwrap();
                let chat = chat_it.next().unwrap();
                let loss = loss_it.next().unwrap();
                jobs.push(Box::new(move || {
                    fused_row_chunk(
                        s, e, d, m, b, tau, absolute, qd, tg, ng, adjust,
                        mask, q_norms, t_norms, dq, dt, rm, rs, ls, tl, tile,
                        chat, loss,
                    );
                }));
            }
            pool.run_wave(jobs);
        }

        // Wave 3: reduce per-worker negative-grad partials, then push the
        // gradient back through the negatives' normalization.
        {
            let chat: &[f32] = &self.chat_part;
            let n_norms = &self.n_norms;
            let ng: &[f32] = neg;
            let mut dn_it = split_chunks(&mut self.d_neg, d, &rn).into_iter();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(rn.len());
            for &(s, e) in &rn {
                let dn = dn_it.next().unwrap();
                jobs.push(Box::new(move || {
                    for w in 0..nq {
                        let part = &chat[w * m * d..][s * d..e * d];
                        simd::axpy(1.0, part, dn);
                    }
                    for r in 0..(e - s) {
                        let y = &ng[(s + r) * d..(s + r + 1) * d];
                        l2norm_bwd_inplace(
                            y,
                            &mut dn[r * d..(r + 1) * d],
                            n_norms[s + r],
                        );
                    }
                }));
            }
            pool.run_wave(jobs);
        }

        let total: f64 = self.loss_part.iter().sum();
        (total / b as f64) as f32
    }
}

/// One row-chunk of the fused step: pass A streams the logsumexp over
/// negative tiles, pass B re-computes each tile (recompute > store) and
/// turns probabilities into gradients, then the target column and the
/// normalization backward close out the chunk's rows.
#[allow(clippy::too_many_arguments)]
fn fused_row_chunk(
    s: usize,
    e: usize,
    d: usize,
    m: usize,
    b: usize,
    tau: f32,
    absolute: bool,
    q: &[f32],
    tgt: &[f32],
    neg: &[f32],
    adjust: &[f32],
    mask: &[f32],
    q_norms: &[f32],
    t_norms: &[f32],
    d_q: &mut [f32],
    d_tgt: &mut [f32],
    row_max: &mut [f64],
    row_sum: &mut [f64],
    lse: &mut [f64],
    tlogit: &mut [f64],
    tile: &mut [f32],
    chat_part: &mut [f32],
    loss_out: &mut f64,
) {
    let rb = e - s;
    let tau64 = tau as f64;
    let tw = TILE.min(m);
    let qs = &q[s * d..e * d];

    // Seed the online logsumexp with the target logit: the target column
    // is part of the softmax (eq. 6) but carries no −log(m·q) correction.
    for r in 0..rb {
        let qr = &q[(s + r) * d..(s + r + 1) * d];
        let tr = &tgt[(s + r) * d..(s + r + 1) * d];
        let mut ot = tau64 * simd::dot(qr, tr) as f64;
        if absolute {
            ot = ot.abs();
        }
        tlogit[r] = ot;
        row_max[r] = ot;
        row_sum[r] = 1.0;
    }

    // Pass A: tile logits, adjust, mask, stream the logsumexp.
    let mut j0 = 0;
    while j0 < m {
        let jl = tw.min(m - j0);
        let tb = &mut tile[..rb * jl];
        simd::matmul_nt_into(qs, rb, d, &neg[j0 * d..(j0 + jl) * d], jl, tb);
        for r in 0..rb {
            let mrow = &mask[(s + r) * m..(s + r + 1) * m];
            let mut mx = row_max[r];
            let mut sum = row_sum[r];
            for j in 0..jl {
                if mrow[j0 + j] == 0.0 {
                    continue; // accidental hit: column drops out
                }
                let mut v =
                    tau64 * tb[r * jl + j] as f64 - adjust[j0 + j] as f64;
                if absolute {
                    v = v.abs();
                }
                if v > mx {
                    sum = sum * (mx - v).exp() + 1.0;
                    mx = v;
                } else {
                    sum += (v - mx).exp();
                }
            }
            row_max[r] = mx;
            row_sum[r] = sum;
        }
        j0 += jl;
    }
    let mut loss = 0.0f64;
    for r in 0..rb {
        let l = row_max[r] + row_sum[r].ln();
        lse[r] = l;
        loss += l - tlogit[r];
    }
    *loss_out += loss;

    // Pass B: recompute each tile, convert probabilities to gradients.
    // coef_j = τ·p_j/B (times sign(o_j) under `absolute`).
    let inv_b = 1.0 / b as f64;
    j0 = 0;
    while j0 < m {
        let jl = tw.min(m - j0);
        let tb = &mut tile[..rb * jl];
        simd::matmul_nt_into(qs, rb, d, &neg[j0 * d..(j0 + jl) * d], jl, tb);
        for r in 0..rb {
            let mrow = &mask[(s + r) * m..(s + r + 1) * m];
            let qr = &q[(s + r) * d..(s + r + 1) * d];
            let dqr = &mut d_q[r * d..(r + 1) * d];
            for j in 0..jl {
                if mrow[j0 + j] == 0.0 {
                    continue;
                }
                let v = tau64 * tb[r * jl + j] as f64 - adjust[j0 + j] as f64;
                let (va, sign) = if absolute {
                    (v.abs(), if v < 0.0 { -1.0 } else { 1.0 })
                } else {
                    (v, 1.0)
                };
                let coef =
                    (tau64 * (va - lse[r]).exp() * inv_b * sign) as f32;
                if coef == 0.0 {
                    continue;
                }
                let cj = &neg[(j0 + j) * d..(j0 + j + 1) * d];
                simd::axpy(coef, cj, dqr);
                simd::axpy(
                    coef,
                    qr,
                    &mut chat_part[(j0 + j) * d..(j0 + j + 1) * d],
                );
            }
        }
        j0 += jl;
    }

    // Target column + normalization backward for the chunk's own rows.
    for r in 0..rb {
        let qr = &q[(s + r) * d..(s + r + 1) * d];
        let tr = &tgt[(s + r) * d..(s + r + 1) * d];
        let pt = (tlogit[r] - lse[r]).exp();
        let sign = if absolute {
            let raw = tau64 * simd::dot(qr, tr) as f64;
            if raw < 0.0 {
                -1.0
            } else {
                1.0
            }
        } else {
            1.0
        };
        let coef = (tau64 * (pt - 1.0) * inv_b * sign) as f32;
        let dqr = &mut d_q[r * d..(r + 1) * d];
        simd::axpy(coef, tr, dqr);
        let dtr = &mut d_tgt[r * d..(r + 1) * d];
        for k in 0..d {
            dtr[k] = coef * qr[k];
        }
        l2norm_bwd_inplace(qr, dqr, q_norms[s + r]);
        l2norm_bwd_inplace(tr, dtr, t_norms[s + r]);
    }
}

// ---------------------------------------------------------------------
// Full-softmax loss (training + eval oracle path)
// ---------------------------------------------------------------------

/// Full-softmax cross-entropy over the whole class table (paper eq. 3):
/// the eval step and the `SamplerKind::Full` train step. Classes are
/// prepared once per call site ([`FullLoss::prepare_classes`], which
/// normalizes into a persistent `cls_hat` copy), then
/// [`FullLoss::forward`] streams a logsumexp over class tiles and
/// [`FullLoss::backward`] re-computes the tiles to accumulate gradients
/// w.r.t. the raw queries and class rows. `normalize = false` is the
/// §4.2 unnormalized ablation (the retired `*_unnorm` artifacts).
pub struct FullLoss {
    workers: usize,
    normalize: bool,
    n: usize,
    d: usize,
    cls_hat: Vec<f32>,
    cls_norms: Vec<f32>,
    q_norms: Vec<f32>,
    row_max: Vec<f64>,
    row_sum: Vec<f64>,
    lse: Vec<f64>,
    tlogit: Vec<f64>,
    loss_part: Vec<f64>,
    tile: Vec<f32>,
    dq_part: Vec<f32>,
    /// `∂L/∂q` (raw query rows), `bsz × d`; valid after `backward`.
    pub d_q: Vec<f32>,
    /// `∂L/∂cls` (raw class rows), `n × d`; valid after `backward`.
    pub d_cls: Vec<f32>,
    growths: u64,
}

impl FullLoss {
    pub fn new(workers: usize) -> Self {
        FullLoss {
            workers: workers.max(1),
            normalize: true,
            n: 0,
            d: 0,
            cls_hat: Vec::new(),
            cls_norms: Vec::new(),
            q_norms: Vec::new(),
            row_max: Vec::new(),
            row_sum: Vec::new(),
            lse: Vec::new(),
            tlogit: Vec::new(),
            loss_part: Vec::new(),
            tile: Vec::new(),
            dq_part: Vec::new(),
            d_q: Vec::new(),
            d_cls: Vec::new(),
            growths: 0,
        }
    }

    /// See [`FusedLoss::growths`].
    pub fn growths(&self) -> u64 {
        self.growths
    }

    /// Copy (and, unless `normalize = false`, L2-normalize) the first
    /// `n` rows of the class table into persistent scratch. Call once
    /// per step / eval pass (the table changes between steps).
    pub fn prepare_classes(
        &mut self,
        cls: &[f32],
        n: usize,
        d: usize,
        normalize: bool,
    ) {
        assert!(n > 0 && d > 0, "FullLoss: empty class table");
        assert!(cls.len() >= n * d, "FullLoss: class table too small");
        self.n = n;
        self.d = d;
        self.normalize = normalize;
        ensure_len(&mut self.cls_hat, n * d, &mut self.growths);
        ensure_len(&mut self.cls_norms, n, &mut self.growths);
        let pool = exec::serve_pool();
        let rn = chunk_ranges(n, self.workers.min(pool.size().max(1)));
        let src = &cls[..n * d];
        let mut hat_it = split_chunks(&mut self.cls_hat, d, &rn).into_iter();
        let mut nrm_it = split_chunks(&mut self.cls_norms, 1, &rn).into_iter();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(rn.len());
        for &(s, e) in &rn {
            let hat = hat_it.next().unwrap();
            let nrm = nrm_it.next().unwrap();
            jobs.push(Box::new(move || {
                hat.copy_from_slice(&src[s * d..e * d]);
                for (i, v) in nrm.iter_mut().enumerate() {
                    *v = if normalize {
                        l2_normalize_eps(&mut hat[i * d..(i + 1) * d])
                    } else {
                        1.0
                    };
                }
            }));
        }
        pool.run_wave(jobs);
    }

    /// Mean full-softmax loss for `q` (`bsz × d`, normalized in place
    /// when the prepared table is) against `targets`. Streams the
    /// logsumexp over class tiles; keeps per-row stats for `backward`.
    pub fn forward(&mut self, q: &mut Matrix, targets: &[u32], tau: f32) -> f32 {
        let (n, d) = (self.n, self.d);
        assert!(n > 0, "FullLoss::forward before prepare_classes");
        let b = q.rows();
        assert_eq!(q.cols(), d, "FullLoss: query dim");
        assert_eq!(targets.len(), b, "FullLoss: targets length");
        let pool = exec::serve_pool();
        let rq = chunk_ranges(b, self.workers.min(pool.size().max(1)));
        let nq = rq.len();
        let rb_max = rq.iter().map(|&(s, e)| e - s).max().unwrap();
        let tw = TILE.min(n);

        ensure_len(&mut self.q_norms, b, &mut self.growths);
        ensure_len(&mut self.row_max, b, &mut self.growths);
        ensure_len(&mut self.row_sum, b, &mut self.growths);
        ensure_len(&mut self.lse, b, &mut self.growths);
        ensure_len(&mut self.tlogit, b, &mut self.growths);
        ensure_len(&mut self.tile, nq * rb_max * tw, &mut self.growths);
        ensure_zeroed(&mut self.loss_part, nq, &mut self.growths);

        if self.normalize {
            let mut q_it = split_chunks(q.data_mut(), d, &rq).into_iter();
            let mut n_it = split_chunks(&mut self.q_norms, 1, &rq).into_iter();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nq);
            for _ in &rq {
                let rows = q_it.next().unwrap();
                let norms = n_it.next().unwrap();
                jobs.push(Box::new(move || {
                    for (i, nrm) in norms.iter_mut().enumerate() {
                        *nrm = l2_normalize_eps(&mut rows[i * d..(i + 1) * d]);
                    }
                }));
            }
            pool.run_wave(jobs);
        }

        {
            let qd: &[f32] = q.data();
            let cls_hat = &self.cls_hat;
            let tau64 = tau as f64;
            let mut rm_it = split_chunks(&mut self.row_max, 1, &rq).into_iter();
            let mut rs_it = split_chunks(&mut self.row_sum, 1, &rq).into_iter();
            let mut ls_it = split_chunks(&mut self.lse, 1, &rq).into_iter();
            let mut tl_it = split_chunks(&mut self.tlogit, 1, &rq).into_iter();
            let mut tile_it = self.tile.chunks_mut(rb_max * tw);
            let mut loss_it = self.loss_part.iter_mut();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nq);
            for &(s, e) in &rq {
                let rm = rm_it.next().unwrap();
                let rs = rs_it.next().unwrap();
                let ls = ls_it.next().unwrap();
                let tl = tl_it.next().unwrap();
                let tile = tile_it.next().unwrap();
                let loss = loss_it.next().unwrap();
                jobs.push(Box::new(move || {
                    let rb = e - s;
                    let qs = &qd[s * d..e * d];
                    for r in 0..rb {
                        let t = targets[s + r] as usize;
                        assert!(t < n, "FullLoss: target {t} out of range");
                        let qr = &qd[(s + r) * d..(s + r + 1) * d];
                        tl[r] = tau64
                            * simd::dot(qr, &cls_hat[t * d..(t + 1) * d])
                                as f64;
                        rm[r] = f64::NEG_INFINITY;
                        rs[r] = 0.0;
                    }
                    let mut j0 = 0;
                    while j0 < n {
                        let jl = tw.min(n - j0);
                        let tb = &mut tile[..rb * jl];
                        simd::matmul_nt_into(
                            qs,
                            rb,
                            d,
                            &cls_hat[j0 * d..(j0 + jl) * d],
                            jl,
                            tb,
                        );
                        for r in 0..rb {
                            let mut mx = rm[r];
                            let mut sum = rs[r];
                            for j in 0..jl {
                                let v = tau64 * tb[r * jl + j] as f64;
                                if v > mx {
                                    sum = sum * (mx - v).exp() + 1.0;
                                    mx = v;
                                } else {
                                    sum += (v - mx).exp();
                                }
                            }
                            rm[r] = mx;
                            rs[r] = sum;
                        }
                        j0 += jl;
                    }
                    let mut lsum = 0.0f64;
                    for r in 0..rb {
                        let l = rm[r] + rs[r].ln();
                        ls[r] = l;
                        lsum += l - tl[r];
                    }
                    *loss += lsum;
                }));
            }
            pool.run_wave(jobs);
        }

        let total: f64 = self.loss_part.iter().sum();
        (total / b as f64) as f32
    }

    /// Gradients for the batch `forward` just ran on (same `q`, already
    /// normalized in place by it, same `targets`): fills `d_q`, `d_cls`.
    pub fn backward(&mut self, q: &Matrix, targets: &[u32], tau: f32) {
        let (n, d) = (self.n, self.d);
        let b = q.rows();
        assert_eq!(self.lse.len(), b, "FullLoss::backward before forward");
        let pool = exec::serve_pool();
        let workers = self.workers.min(pool.size().max(1));
        let rn = chunk_ranges(n, workers);
        let nn = rn.len();
        let tw = TILE.min(n);

        ensure_zeroed(&mut self.d_q, b * d, &mut self.growths);
        ensure_zeroed(&mut self.d_cls, n * d, &mut self.growths);
        ensure_zeroed(&mut self.dq_part, nn * b * d, &mut self.growths);
        ensure_len(&mut self.tile, nn * b * tw, &mut self.growths);

        // Class-chunk wave: each job owns its class rows' gradients and
        // a whole-batch d_q partial (reduced in the wave after).
        {
            let qd: &[f32] = q.data();
            let cls_hat = &self.cls_hat;
            let cls_norms = &self.cls_norms;
            let lse = &self.lse;
            let normalize = self.normalize;
            let tau64 = tau as f64;
            let inv_b = 1.0 / b as f64;
            let mut dc_it = split_chunks(&mut self.d_cls, d, &rn).into_iter();
            let mut dqp_it = self.dq_part.chunks_mut(b * d);
            let mut tile_it = self.tile.chunks_mut(b * tw);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nn);
            for &(s, e) in &rn {
                let dc = dc_it.next().unwrap();
                let dqp = dqp_it.next().unwrap();
                let tile = tile_it.next().unwrap();
                jobs.push(Box::new(move || {
                    let mut j0 = s;
                    while j0 < e {
                        let jl = tw.min(e - j0);
                        let tb = &mut tile[..b * jl];
                        simd::matmul_nt_into(
                            qd,
                            b,
                            d,
                            &cls_hat[j0 * d..(j0 + jl) * d],
                            jl,
                            tb,
                        );
                        for r in 0..b {
                            let t = targets[r] as usize;
                            let qr = &qd[r * d..(r + 1) * d];
                            let dqr = &mut dqp[r * d..(r + 1) * d];
                            for j in 0..jl {
                                let v = tau64 * tb[r * jl + j] as f64;
                                let p = (v - lse[r]).exp();
                                let mut coef = tau64 * p * inv_b;
                                if t == j0 + j {
                                    coef -= tau64 * inv_b;
                                }
                                let cf = coef as f32;
                                if cf == 0.0 {
                                    continue;
                                }
                                let cj = &cls_hat
                                    [(j0 + j) * d..(j0 + j + 1) * d];
                                simd::axpy(cf, cj, dqr);
                                simd::axpy(
                                    cf,
                                    qr,
                                    &mut dc[(j0 + j - s) * d
                                        ..(j0 + j - s + 1) * d],
                                );
                            }
                        }
                        j0 += jl;
                    }
                    if normalize {
                        for r in 0..(e - s) {
                            let y = &cls_hat[(s + r) * d..(s + r + 1) * d];
                            l2norm_bwd_inplace(
                                y,
                                &mut dc[r * d..(r + 1) * d],
                                cls_norms[s + r],
                            );
                        }
                    }
                }));
            }
            pool.run_wave(jobs);
        }

        // Row-chunk reduce wave: d_q rows = Σ per-worker partials, then
        // back through the query normalization.
        {
            let rq = chunk_ranges(b, workers);
            let dq_part: &[f32] = &self.dq_part;
            let q_norms = &self.q_norms;
            let qd: &[f32] = q.data();
            let normalize = self.normalize;
            let mut dq_it = split_chunks(&mut self.d_q, d, &rq).into_iter();
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(rq.len());
            for &(s, e) in &rq {
                let dq = dq_it.next().unwrap();
                jobs.push(Box::new(move || {
                    for w in 0..nn {
                        let part = &dq_part[w * b * d..][s * d..e * d];
                        simd::axpy(1.0, part, dq);
                    }
                    if normalize {
                        for r in 0..(e - s) {
                            let y = &qd[(s + r) * d..(s + r + 1) * d];
                            l2norm_bwd_inplace(
                                y,
                                &mut dq[r * d..(r + 1) * d],
                                q_norms[s + r],
                            );
                        }
                    }
                }));
            }
            pool.run_wave(jobs);
        }
    }

    /// Score every class for every query row (`out` is `bsz × n`,
    /// row-major): the XC eval path. Normalizes `q` in place when the
    /// prepared table is normalized. Scores are `q̂ · ĉ_j` (no τ — it is
    /// monotone in the ranking).
    pub fn scores_into(&mut self, q: &mut Matrix, out: &mut [f32]) {
        let (n, d) = (self.n, self.d);
        assert!(n > 0, "FullLoss::scores_into before prepare_classes");
        let b = q.rows();
        assert_eq!(q.cols(), d, "FullLoss: query dim");
        assert_eq!(out.len(), b * n, "FullLoss: scores shape");
        let pool = exec::serve_pool();
        let rq = chunk_ranges(b, self.workers.min(pool.size().max(1)));
        let cls_hat = &self.cls_hat;
        let normalize = self.normalize;
        let mut q_it = split_chunks(q.data_mut(), d, &rq).into_iter();
        let mut out_it = split_chunks(out, n, &rq).into_iter();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(rq.len());
        for &(s, e) in &rq {
            let rows = q_it.next().unwrap();
            let orows = out_it.next().unwrap();
            jobs.push(Box::new(move || {
                let rb = e - s;
                if normalize {
                    for i in 0..rb {
                        l2_normalize_eps(&mut rows[i * d..(i + 1) * d]);
                    }
                }
                simd::matmul_nt_into(rows, rb, d, cls_hat, n, orows);
            }));
        }
        pool.run_wave(jobs);
    }
}

// ---------------------------------------------------------------------
// LSTM encoder step (forward + truncated BPTT backward)
// ---------------------------------------------------------------------

/// The LM encoder kernel: context embeddings → single-layer LSTM
/// (gate order i, f, g, o; `model.py::lm_*` semantics) → projection to
/// the query `u` (`bsz × d`). Forward caches gates/cells/hiddens so one
/// encoder pass serves both the sampler draw and the loss; `backward`
/// runs BPTT and produces dense weight grads plus per-(row, t) input
/// grads for the embedding scatter.
///
/// Activations are stored **chunk-block-major**: the rows of pool chunk
/// `[s, e)` occupy one contiguous block, t-major inside (`(b, t)` at
/// `(s·l + t·rb + (b−s))·width`), so each wave job reads and writes only
/// its own contiguous block and every per-`t` gemm gets a contiguous
/// `rb×width` operand. [`LmStep::x_offset`] maps `(row, t)` into this
/// layout for the gather/scatter side.
pub struct LmStep {
    workers: usize,
    b: usize,
    l: usize,
    d: usize,
    h: usize,
    ranges: Vec<(usize, usize)>,
    /// Per batch row: (chunk start, chunk rows, index within chunk).
    row_loc: Vec<(usize, usize, usize)>,
    wxt: Vec<f32>,
    wht: Vec<f32>,
    projt: Vec<f32>,
    x: Vec<f32>,
    gates: Vec<f32>,
    cells: Vec<f32>,
    hs: Vec<f32>,
    gbuf: Vec<f32>,
    gbuf2: Vec<f32>,
    hbuf: Vec<f32>,
    cbuf: Vec<f32>,
    wpart: Vec<f32>,
    d_x: Vec<f32>,
    /// Encoder output `u` (`bsz × d`), valid after `forward`.
    pub u: Matrix,
    /// `∂L/∂wx` (`d × 4h`), valid after `backward`.
    pub dwx: Vec<f32>,
    /// `∂L/∂wh` (`h × 4h`), valid after `backward`.
    pub dwh: Vec<f32>,
    /// `∂L/∂bias` (`4h`), valid after `backward`.
    pub db: Vec<f32>,
    /// `∂L/∂proj` (`h × d`), valid after `backward`.
    pub dproj: Vec<f32>,
    growths: u64,
}

impl LmStep {
    pub fn new(workers: usize) -> Self {
        LmStep {
            workers: workers.max(1),
            b: 0,
            l: 0,
            d: 0,
            h: 0,
            ranges: Vec::new(),
            row_loc: Vec::new(),
            wxt: Vec::new(),
            wht: Vec::new(),
            projt: Vec::new(),
            x: Vec::new(),
            gates: Vec::new(),
            cells: Vec::new(),
            hs: Vec::new(),
            gbuf: Vec::new(),
            gbuf2: Vec::new(),
            hbuf: Vec::new(),
            cbuf: Vec::new(),
            wpart: Vec::new(),
            d_x: Vec::new(),
            u: Matrix::zeros(1, 1),
            dwx: Vec::new(),
            dwh: Vec::new(),
            db: Vec::new(),
            dproj: Vec::new(),
            growths: 0,
        }
    }

    /// See [`FusedLoss::growths`].
    pub fn growths(&self) -> u64 {
        self.growths
    }

    /// Size the step for a `(bsz, seq_len, dim, hidden)` batch; after
    /// this, fill the input block via [`LmStep::load_rows`] (or
    /// `x_offset` directly) and call `forward`.
    pub fn begin(&mut self, b: usize, l: usize, d: usize, h: usize) {
        assert!(b > 0 && l > 0 && d > 0 && h > 0, "LmStep: empty shape");
        if self.b != b {
            self.growths += 1; // ranges + row_loc rebuild
            self.ranges = chunk_ranges(b, self.workers);
            self.row_loc.clear();
            self.row_loc.reserve(b);
            for &(s, e) in &self.ranges {
                for r in s..e {
                    self.row_loc.push((s, e - s, r - s));
                }
            }
        }
        self.b = b;
        self.l = l;
        self.d = d;
        self.h = h;
        let fh = 4 * h;
        ensure_len(&mut self.x, b * l * d, &mut self.growths);
        ensure_len(&mut self.gates, b * l * fh, &mut self.growths);
        ensure_len(&mut self.cells, b * (l + 1) * h, &mut self.growths);
        ensure_len(&mut self.hs, b * (l + 1) * h, &mut self.growths);
        if self.u.rows() != b || self.u.cols() != d {
            self.u = Matrix::zeros(b, d);
            self.growths += 1;
        }
    }

    /// Element offset of `(row, t)`'s input vector inside the blocked
    /// `x` / `d_x` buffers.
    pub fn x_offset(&self, row: usize, t: usize) -> usize {
        let (s, rb, idx) = self.row_loc[row];
        (s * self.l + t * rb + idx) * self.d
    }

    /// The input block, to be filled before `forward` (layout per
    /// [`LmStep::x_offset`]).
    pub fn x_mut(&mut self) -> &mut [f32] {
        &mut self.x
    }

    /// Gather `ids` (`bsz·seq_len`, `(row, t)` row-major) from a flat
    /// embedding table straight into the blocked input buffer.
    pub fn load_rows(&mut self, table: &[f32], ids: &[u32]) {
        assert_eq!(ids.len(), self.b * self.l, "LmStep: ids length");
        let (l, d) = (self.l, self.d);
        for (i, &id) in ids.iter().enumerate() {
            let off = self.x_offset(i / l, i % l);
            let s = id as usize * d;
            self.x[off..off + d].copy_from_slice(&table[s..s + d]);
        }
    }

    /// `(row, t)`'s input gradient after `backward` (for the embedding
    /// scatter).
    pub fn d_x_row(&self, row: usize, t: usize) -> &[f32] {
        let off = self.x_offset(row, t);
        &self.d_x[off..off + self.d]
    }

    /// LSTM forward over the loaded inputs: fills the activation caches
    /// and `u`. Weights are row-major: `wx` `d×4h`, `wh` `h×4h`, `bias`
    /// `4h`, `proj` `h×d`.
    pub fn forward(&mut self, wx: &[f32], wh: &[f32], bias: &[f32], proj: &[f32]) {
        let (l, d, h) = (self.l, self.d, self.h);
        let fh = 4 * h;
        assert_eq!(wx.len(), d * fh, "LmStep: wx shape");
        assert_eq!(wh.len(), h * fh, "LmStep: wh shape");
        assert_eq!(bias.len(), fh, "LmStep: bias shape");
        assert_eq!(proj.len(), h * d, "LmStep: proj shape");
        transpose_into(wx, d, fh, &mut self.wxt, &mut self.growths);
        transpose_into(wh, h, fh, &mut self.wht, &mut self.growths);
        transpose_into(proj, h, d, &mut self.projt, &mut self.growths);
        let nq = self.ranges.len();
        let rb_max =
            self.ranges.iter().map(|&(s, e)| e - s).max().unwrap();
        ensure_len(&mut self.gbuf, nq * rb_max * fh, &mut self.growths);
        ensure_len(&mut self.gbuf2, nq * rb_max * fh, &mut self.growths);

        let x: &[f32] = &self.x;
        let wxt: &[f32] = &self.wxt;
        let wht: &[f32] = &self.wht;
        let projt: &[f32] = &self.projt;
        let mut g_it = split_chunks(&mut self.gates, l * fh, &self.ranges)
            .into_iter();
        let mut c_it =
            split_chunks(&mut self.cells, (l + 1) * h, &self.ranges)
                .into_iter();
        let mut h_it = split_chunks(&mut self.hs, (l + 1) * h, &self.ranges)
            .into_iter();
        let mut u_it =
            split_chunks(self.u.data_mut(), d, &self.ranges).into_iter();
        let mut g1_it = self.gbuf.chunks_mut(rb_max * fh);
        let mut g2_it = self.gbuf2.chunks_mut(rb_max * fh);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(nq);
        for &(s, e) in &self.ranges {
            let gb = g_it.next().unwrap();
            let cb = c_it.next().unwrap();
            let hb = h_it.next().unwrap();
            let ub = u_it.next().unwrap();
            let g1 = g1_it.next().unwrap();
            let g2 = g2_it.next().unwrap();
            jobs.push(Box::new(move || {
                lm_forward_chunk(
                    s, e, l, d, h, x, wxt, wht, bias, projt, gb, cb, hb, ub,
                    g1, g2,
                );
            }));
        }
        exec::serve_pool().run_wave(jobs);
    }

    /// BPTT from `d_u` (`bsz × d`, e.g. [`FusedLoss::d_q`]) through the
    /// cached forward: fills `d_x` (read via [`LmStep::d_x_row`]) and
    /// the dense weight grads `dwx`/`dwh`/`db`/`dproj`.
    pub fn backward(&mut self, wx: &[f32], wh: &[f32], proj: &[f32], d_u: &[f32]) {
        let (b, l, d, h) = (self.b, self.l, self.d, self.h);
        let fh = 4 * h;
        assert_eq!(d_u.len(), b * d, "LmStep: d_u shape");
        let nq = self.ranges.len();
        let rb_max =
            self.ranges.iter().map(|&(s, e)| e - s).max().unwrap();
        let psz = d * fh + h * fh + fh + h * d;
        ensure_len(&mut self.d_x, b * l * d, &mut self.growths);
        ensure_zeroed(&mut self.wpart, nq * psz, &mut self.growths);
        ensure_len(&mut self.hbuf, nq * rb_max * h, &mut self.growths);
        ensure_len(&mut self.cbuf, nq * rb_max * h, &mut self.growths);
        ensure_len(&mut self.gbuf, nq * rb_max * fh, &mut self.growths);
        ensure_zeroed(&mut self.dwx, d * fh, &mut self.growths);
        ensure_zeroed(&mut self.dwh, h * fh, &mut self.growths);
        ensure_zeroed(&mut self.db, fh, &mut self.growths);
        ensure_zeroed(&mut self.dproj, h * d, &mut self.growths);

        {
            let x: &[f32] = &self.x;
            let gates: &[f32] = &self.gates;
            let cells: &[f32] = &self.cells;
            let hs: &[f32] = &self.hs;
            let mut dx_it =
                split_chunks(&mut self.d_x, l * d, &self.ranges).into_iter();
            let mut dh_it = self.hbuf.chunks_mut(rb_max * h);
            let mut dc_it = self.cbuf.chunks_mut(rb_max * h);
            let mut dg_it = self.gbuf.chunks_mut(rb_max * fh);
            let mut wp_it = self.wpart.chunks_mut(psz);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(nq);
            for &(s, e) in &self.ranges {
                let dxb = dx_it.next().unwrap();
                let dh = dh_it.next().unwrap();
                let dc = dc_it.next().unwrap();
                let dg = dg_it.next().unwrap();
                let wp = wp_it.next().unwrap();
                jobs.push(Box::new(move || {
                    lm_backward_chunk(
                        s, e, l, d, h, x, gates, cells, hs, wx, wh, proj,
                        d_u, dxb, dh, dc, dg, wp,
                    );
                }));
            }
            exec::serve_pool().run_wave(jobs);
        }

        // Deterministic serial reduce of the per-worker weight partials.
        for w in 0..nq {
            let part = &self.wpart[w * psz..(w + 1) * psz];
            simd::axpy(1.0, &part[..d * fh], &mut self.dwx);
            simd::axpy(1.0, &part[d * fh..(d + h) * fh], &mut self.dwh);
            simd::axpy(
                1.0,
                &part[(d + h) * fh..(d + h + 1) * fh],
                &mut self.db,
            );
            simd::axpy(1.0, &part[(d + h + 1) * fh..], &mut self.dproj);
        }
    }
}

/// Forward one row chunk: per-`t` gate gemms (`x_t·wxᵀ`, `h_{t−1}·whᵀ`),
/// activations, state update, then the last hidden's projection.
#[allow(clippy::too_many_arguments)]
fn lm_forward_chunk(
    s: usize,
    e: usize,
    l: usize,
    d: usize,
    h: usize,
    x: &[f32],
    wxt: &[f32],
    wht: &[f32],
    bias: &[f32],
    projt: &[f32],
    gates: &mut [f32],
    cells: &mut [f32],
    hs: &mut [f32],
    u: &mut [f32],
    g1: &mut [f32],
    g2: &mut [f32],
) {
    let rb = e - s;
    let fh = 4 * h;
    let xb = &x[s * l * d..e * l * d];
    hs[..rb * h].fill(0.0);
    cells[..rb * h].fill(0.0);
    for t in 0..l {
        let xt = &xb[t * rb * d..(t + 1) * rb * d];
        let g1t = &mut g1[..rb * fh];
        simd::matmul_nt_into(xt, rb, d, wxt, fh, g1t);
        let (hlo, hhi) = hs.split_at_mut((t + 1) * rb * h);
        let hprev = &hlo[t * rb * h..];
        let g2t = &mut g2[..rb * fh];
        simd::matmul_nt_into(hprev, rb, h, wht, fh, g2t);
        let (clo, chi) = cells.split_at_mut((t + 1) * rb * h);
        let cprev = &clo[t * rb * h..];
        let cnext = &mut chi[..rb * h];
        let hnext = &mut hhi[..rb * h];
        for r in 0..rb {
            let grow = &mut gates[(t * rb + r) * fh..(t * rb + r + 1) * fh];
            let a = &g1t[r * fh..(r + 1) * fh];
            let c = &g2t[r * fh..(r + 1) * fh];
            for j in 0..fh {
                grow[j] = a[j] + c[j] + bias[j];
            }
            // Saved post-activation (what the backward needs).
            for k in 0..h {
                let i = sigmoid(grow[k]);
                let f = sigmoid(grow[h + k]);
                let g = grow[2 * h + k].tanh();
                let o = sigmoid(grow[3 * h + k]);
                grow[k] = i;
                grow[h + k] = f;
                grow[2 * h + k] = g;
                grow[3 * h + k] = o;
                let cv = f * cprev[r * h + k] + i * g;
                cnext[r * h + k] = cv;
                hnext[r * h + k] = o * cv.tanh();
            }
        }
    }
    let hlast = &hs[l * rb * h..(l + 1) * rb * h];
    simd::matmul_nt_into(hlast, rb, h, projt, d, u);
}

/// Backward one row chunk: dh from the projection, then BPTT over `t`
/// with gate-gradient gemms producing `d_x_t` and `dh_{t−1}` and axpy
/// rank-1 accumulation into the chunk's weight partials.
#[allow(clippy::too_many_arguments)]
fn lm_backward_chunk(
    s: usize,
    e: usize,
    l: usize,
    d: usize,
    h: usize,
    x: &[f32],
    gates: &[f32],
    cells: &[f32],
    hs: &[f32],
    wx: &[f32],
    wh: &[f32],
    proj: &[f32],
    d_u: &[f32],
    d_x: &mut [f32],
    dh: &mut [f32],
    dc: &mut [f32],
    dg: &mut [f32],
    wpart: &mut [f32],
) {
    let rb = e - s;
    let fh = 4 * h;
    let xb = &x[s * l * d..e * l * d];
    let gb = &gates[s * l * fh..e * l * fh];
    let cb = &cells[s * (l + 1) * h..e * (l + 1) * h];
    let hb = &hs[s * (l + 1) * h..e * (l + 1) * h];
    let dur = &d_u[s * d..e * d];
    let dh = &mut dh[..rb * h];
    let dc = &mut dc[..rb * h];
    let dg = &mut dg[..rb * fh];
    dc.fill(0.0);
    simd::matmul_nt_into(dur, rb, d, proj, h, dh);
    let (pwx, rest) = wpart.split_at_mut(d * fh);
    let (pwh, rest) = rest.split_at_mut(h * fh);
    let (pb, pproj) = rest.split_at_mut(fh);
    let hlast = &hb[l * rb * h..];
    for r in 0..rb {
        let durow = &dur[r * d..(r + 1) * d];
        for k in 0..h {
            simd::axpy(hlast[r * h + k], durow, &mut pproj[k * d..(k + 1) * d]);
        }
    }
    for t in (0..l).rev() {
        for r in 0..rb {
            let grow = &gb[(t * rb + r) * fh..(t * rb + r + 1) * fh];
            let cnext = &cb[((t + 1) * rb + r) * h..((t + 1) * rb + r + 1) * h];
            let cprev = &cb[(t * rb + r) * h..(t * rb + r + 1) * h];
            for k in 0..h {
                let i = grow[k];
                let f = grow[h + k];
                let g = grow[2 * h + k];
                let o = grow[3 * h + k];
                let tc = cnext[k].tanh();
                let dhk = dh[r * h + k];
                let dck = dc[r * h + k] + dhk * o * (1.0 - tc * tc);
                dg[r * fh + k] = dck * g * i * (1.0 - i);
                dg[r * fh + h + k] = dck * cprev[k] * f * (1.0 - f);
                dg[r * fh + 2 * h + k] = dck * i * (1.0 - g * g);
                dg[r * fh + 3 * h + k] = dhk * tc * o * (1.0 - o);
                dc[r * h + k] = dck * f;
            }
        }
        let dxt = &mut d_x[t * rb * d..(t + 1) * rb * d];
        simd::matmul_nt_into(dg, rb, fh, wx, d, dxt);
        simd::matmul_nt_into(dg, rb, fh, wh, h, dh);
        for r in 0..rb {
            let dgrow = &dg[r * fh..(r + 1) * fh];
            let xrow = &xb[(t * rb + r) * d..(t * rb + r + 1) * d];
            for k in 0..d {
                simd::axpy(xrow[k], dgrow, &mut pwx[k * fh..(k + 1) * fh]);
            }
            let hprev = &hb[(t * rb + r) * h..(t * rb + r + 1) * h];
            for k in 0..h {
                simd::axpy(hprev[k], dgrow, &mut pwh[k * fh..(k + 1) * fh]);
            }
            simd::axpy(1.0, dgrow, pb);
        }
    }
}

// ---------------------------------------------------------------------
// XC encoder step (sparse features → dense query)
// ---------------------------------------------------------------------

/// The extreme-classification encoder: `u_r = Σ_j vals[r,j]·W[feats[r,j]]`
/// (a sparse gather-accumulate over [`crate::linalg::axpy_rows`]) and
/// its backward `d_feat[r,j] = vals[r,j]·d_u_r` for the sparse scatter.
pub struct XcStep {
    workers: usize,
    /// Encoder output `u` (`bsz × d`), valid after `forward`.
    pub u: Matrix,
    /// Per-(row, feature-slot) input grads (`bsz·nnz × d`), valid after
    /// `feat_grad`.
    pub d_feat: Vec<f32>,
    growths: u64,
}

impl XcStep {
    pub fn new(workers: usize) -> Self {
        XcStep {
            workers: workers.max(1),
            u: Matrix::zeros(1, 1),
            d_feat: Vec::new(),
            growths: 0,
        }
    }

    /// See [`FusedLoss::growths`].
    pub fn growths(&self) -> u64 {
        self.growths
    }

    pub fn forward(
        &mut self,
        w: &[f32],
        d: usize,
        feats: &[u32],
        vals: &[f32],
        bsz: usize,
        nnz: usize,
    ) {
        assert_eq!(feats.len(), bsz * nnz, "XcStep: feats shape");
        assert_eq!(vals.len(), bsz * nnz, "XcStep: vals shape");
        if self.u.rows() != bsz || self.u.cols() != d {
            self.u = Matrix::zeros(bsz, d);
            self.growths += 1;
        }
        let pool = exec::serve_pool();
        let rq = chunk_ranges(bsz, self.workers.min(pool.size().max(1)));
        let mut u_it = split_chunks(self.u.data_mut(), d, &rq).into_iter();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(rq.len());
        for &(s, e) in &rq {
            let ub = u_it.next().unwrap();
            jobs.push(Box::new(move || {
                for r in 0..(e - s) {
                    let row = &mut ub[r * d..(r + 1) * d];
                    row.fill(0.0);
                    crate::linalg::axpy_rows(
                        w,
                        d,
                        &feats[(s + r) * nnz..(s + r + 1) * nnz],
                        &vals[(s + r) * nnz..(s + r + 1) * nnz],
                        row,
                    );
                }
            }));
        }
        pool.run_wave(jobs);
    }

    pub fn feat_grad(
        &mut self,
        d_u: &[f32],
        vals: &[f32],
        bsz: usize,
        nnz: usize,
        d: usize,
    ) {
        assert_eq!(d_u.len(), bsz * d, "XcStep: d_u shape");
        assert_eq!(vals.len(), bsz * nnz, "XcStep: vals shape");
        ensure_len(&mut self.d_feat, bsz * nnz * d, &mut self.growths);
        let pool = exec::serve_pool();
        let rq = chunk_ranges(bsz, self.workers.min(pool.size().max(1)));
        let mut df_it =
            split_chunks(&mut self.d_feat, nnz * d, &rq).into_iter();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(rq.len());
        for &(s, e) in &rq {
            let dfb = df_it.next().unwrap();
            jobs.push(Box::new(move || {
                for r in 0..(e - s) {
                    let durow = &d_u[(s + r) * d..(s + r + 1) * d];
                    for j in 0..nnz {
                        let v = vals[(s + r) * nnz + j];
                        let out = &mut dfb[(r * nnz + j) * d
                            ..(r * nnz + j + 1) * d];
                        for (ov, &dv) in out.iter_mut().zip(durow) {
                            *ov = v * dv;
                        }
                    }
                }
            }));
        }
        pool.run_wave(jobs);
    }
}

// ---------------------------------------------------------------------
// Composed (unfused) reference pipeline
// ---------------------------------------------------------------------

/// The *composed* baseline: the same math as the fused kernels, written
/// the way the retired artifact pipeline staged it — serial, stage by
/// stage, materializing every intermediate (normalized copies, the full
/// `bsz×(1+m)` logit matrix, probability rows) and allocating fresh
/// buffers per call. Still gemm-backed (`Matrix::matmul_nt` over the
/// same SIMD microkernels), so `bench-check --require-fused-speedup`
/// measures fusion + scratch reuse + fan-out, not a strawman.
///
/// Doubles as an independent implementation for the equivalence tests.
pub mod composed {
    use super::{l2_normalize_eps, l2norm_bwd_inplace, sigmoid};
    use crate::linalg::{logsumexp, simd, softmax, Matrix};

    /// Loss + grads of one sampled-softmax step (see [`super::FusedLoss`]).
    pub struct SampledOut {
        pub loss: f32,
        pub d_q: Vec<f32>,
        pub d_tgt: Vec<f32>,
        pub d_neg: Vec<f32>,
    }

    /// Unfused sampled-softmax loss/grad: normalize → full logit matrix
    /// → adjust/mask matrix → per-row softmax → gradient scatter, each
    /// stage a fresh allocation.
    pub fn sampled_loss_grad(
        q: &Matrix,
        tgt: &[f32],
        neg: &[f32],
        adjust: &[f32],
        mask: &[f32],
        tau: f32,
        absolute: bool,
    ) -> SampledOut {
        let b = q.rows();
        let d = q.cols();
        let m = adjust.len();
        let tau64 = tau as f64;
        // Stage 1: normalized copies.
        let mut qn = q.data().to_vec();
        let mut tn = tgt.to_vec();
        let mut cn = neg.to_vec();
        let mut q_norms = vec![0.0f32; b];
        let mut t_norms = vec![0.0f32; b];
        let mut c_norms = vec![0.0f32; m];
        for r in 0..b {
            q_norms[r] = l2_normalize_eps(&mut qn[r * d..(r + 1) * d]);
            t_norms[r] = l2_normalize_eps(&mut tn[r * d..(r + 1) * d]);
        }
        for j in 0..m {
            c_norms[j] = l2_normalize_eps(&mut cn[j * d..(j + 1) * d]);
        }
        // Stage 2: the full bsz×m negative-logit matrix (one gemm).
        let qm = Matrix::from_vec(b, d, qn.clone());
        let cm = Matrix::from_vec(m, d, cn.clone());
        let raw = qm.matmul_nt(&cm);
        // Stages 3–5: adjusted logit rows, per-row softmax, gradients.
        let mut loss = 0.0f64;
        let mut d_q = vec![0.0f32; b * d];
        let mut d_tgt = vec![0.0f32; b * d];
        let mut d_neg = vec![0.0f32; m * d];
        let inv_b = 1.0 / b as f64;
        for r in 0..b {
            let qr = &qn[r * d..(r + 1) * d];
            let tr = &tn[r * d..(r + 1) * d];
            let ot_raw = tau64 * simd::dot(qr, tr) as f64;
            // Adjusted row: [o_t, o_j − log(m·q_j)], masked → −∞.
            let mut row = Vec::with_capacity(m + 1);
            let mut signs = Vec::with_capacity(m + 1);
            let (ot, ts) = if absolute {
                (ot_raw.abs(), if ot_raw < 0.0 { -1.0 } else { 1.0 })
            } else {
                (ot_raw, 1.0)
            };
            row.push(ot);
            signs.push(ts);
            for j in 0..m {
                if mask[r * m + j] == 0.0 {
                    row.push(f64::NEG_INFINITY);
                    signs.push(1.0);
                    continue;
                }
                let v =
                    tau64 * raw.get(r, j) as f64 - adjust[j] as f64;
                if absolute {
                    row.push(v.abs());
                    signs.push(if v < 0.0 { -1.0 } else { 1.0 });
                } else {
                    row.push(v);
                    signs.push(1.0);
                }
            }
            loss += logsumexp(&row) - row[0];
            let probs = softmax(&row);
            // d_q̂, d_t̂, d_ĉ in normalized coordinates.
            let mut dq_hat = vec![0.0f32; d];
            let coef_t = (tau64 * (probs[0] - 1.0) * inv_b * signs[0]) as f32;
            simd::axpy(coef_t, tr, &mut dq_hat);
            let mut dt_hat = vec![0.0f32; d];
            for k in 0..d {
                dt_hat[k] = coef_t * qr[k];
            }
            for j in 0..m {
                let coef =
                    (tau64 * probs[j + 1] * inv_b * signs[j + 1]) as f32;
                if coef == 0.0 {
                    continue;
                }
                simd::axpy(coef, &cn[j * d..(j + 1) * d], &mut dq_hat);
                simd::axpy(coef, qr, &mut d_neg[j * d..(j + 1) * d]);
            }
            l2norm_bwd_inplace(qr, &mut dq_hat, q_norms[r]);
            l2norm_bwd_inplace(tr, &mut dt_hat, t_norms[r]);
            d_q[r * d..(r + 1) * d].copy_from_slice(&dq_hat);
            d_tgt[r * d..(r + 1) * d].copy_from_slice(&dt_hat);
        }
        // Stage 6: negatives back through their normalization.
        for j in 0..m {
            let y = &cn[j * d..(j + 1) * d];
            l2norm_bwd_inplace(y, &mut d_neg[j * d..(j + 1) * d], c_norms[j]);
        }
        SampledOut {
            loss: (loss / b as f64) as f32,
            d_q,
            d_tgt,
            d_neg,
        }
    }

    /// The cached activations of one serial LSTM forward. Layouts are
    /// plain `(row, t)` row-major (`gates[(r·l + t)·4h..]` etc.).
    pub struct LmFwd {
        pub gates: Vec<f32>,
        pub cells: Vec<f32>,
        pub hs: Vec<f32>,
        pub u: Matrix,
    }

    /// Serial LSTM forward, fresh transposes and buffers per call
    /// (mirroring the per-step `block_tensor` clones of the old path).
    /// `x` is `(row, t)` row-major `bsz·l × d`.
    #[allow(clippy::too_many_arguments)]
    pub fn lm_forward(
        x: &[f32],
        b: usize,
        l: usize,
        d: usize,
        h: usize,
        wx: &[f32],
        wh: &[f32],
        bias: &[f32],
        proj: &[f32],
    ) -> LmFwd {
        let fh = 4 * h;
        assert_eq!(x.len(), b * l * d);
        let wxt = Matrix::from_vec(d, fh, wx.to_vec()).transpose();
        let wht = Matrix::from_vec(h, fh, wh.to_vec()).transpose();
        let projt = Matrix::from_vec(h, d, proj.to_vec()).transpose();
        let mut gates = vec![0.0f32; b * l * fh];
        let mut cells = vec![0.0f32; b * (l + 1) * h];
        let mut hs = vec![0.0f32; b * (l + 1) * h];
        let mut u = Matrix::zeros(b, d);
        for r in 0..b {
            for t in 0..l {
                let g1 = {
                    let xt = &x[(r * l + t) * d..(r * l + t + 1) * d];
                    let mut g = vec![0.0f32; fh];
                    simd::matmul_nt_into(xt, 1, d, wxt.data(), fh, &mut g);
                    g
                };
                let g2 = {
                    let hp = hs[(r * (l + 1) + t) * h..(r * (l + 1) + t + 1) * h]
                        .to_vec();
                    let mut g = vec![0.0f32; fh];
                    simd::matmul_nt_into(&hp, 1, h, wht.data(), fh, &mut g);
                    g
                };
                let grow = &mut gates[(r * l + t) * fh..(r * l + t + 1) * fh];
                for j in 0..fh {
                    grow[j] = g1[j] + g2[j] + bias[j];
                }
                for k in 0..h {
                    let i = sigmoid(grow[k]);
                    let f = sigmoid(grow[h + k]);
                    let g = grow[2 * h + k].tanh();
                    let o = sigmoid(grow[3 * h + k]);
                    grow[k] = i;
                    grow[h + k] = f;
                    grow[2 * h + k] = g;
                    grow[3 * h + k] = o;
                    let cv = f * cells[(r * (l + 1) + t) * h + k] + i * g;
                    cells[(r * (l + 1) + t + 1) * h + k] = cv;
                    hs[(r * (l + 1) + t + 1) * h + k] = o * cv.tanh();
                }
            }
            let hl =
                hs[(r * (l + 1) + l) * h..(r * (l + 1) + l + 1) * h].to_vec();
            simd::matmul_nt_into(&hl, 1, h, projt.data(), d, u.row_mut(r));
        }
        LmFwd { gates, cells, hs, u }
    }

    /// Gradients of one serial BPTT pass (see [`super::LmStep::backward`]).
    pub struct LmGrads {
        pub d_x: Vec<f32>,
        pub dwx: Vec<f32>,
        pub dwh: Vec<f32>,
        pub db: Vec<f32>,
        pub dproj: Vec<f32>,
    }

    /// Serial BPTT mirror of the fused backward, fresh buffers per call.
    #[allow(clippy::too_many_arguments)]
    pub fn lm_backward(
        st: &LmFwd,
        x: &[f32],
        b: usize,
        l: usize,
        d: usize,
        h: usize,
        wx: &[f32],
        wh: &[f32],
        proj: &[f32],
        d_u: &[f32],
    ) -> LmGrads {
        let fh = 4 * h;
        let mut d_x = vec![0.0f32; b * l * d];
        let mut dwx = vec![0.0f32; d * fh];
        let mut dwh = vec![0.0f32; h * fh];
        let mut db = vec![0.0f32; fh];
        let mut dproj = vec![0.0f32; h * d];
        for r in 0..b {
            let durow = &d_u[r * d..(r + 1) * d];
            let mut dh = vec![0.0f32; h];
            simd::matmul_nt_into(durow, 1, d, proj, h, &mut dh);
            let hl = &st.hs[(r * (l + 1) + l) * h..(r * (l + 1) + l + 1) * h];
            for k in 0..h {
                simd::axpy(hl[k], durow, &mut dproj[k * d..(k + 1) * d]);
            }
            let mut dc = vec![0.0f32; h];
            let mut dgates = vec![0.0f32; fh];
            for t in (0..l).rev() {
                let grow = &st.gates[(r * l + t) * fh..(r * l + t + 1) * fh];
                let cnext = &st.cells
                    [(r * (l + 1) + t + 1) * h..(r * (l + 1) + t + 2) * h];
                let cprev = &st.cells
                    [(r * (l + 1) + t) * h..(r * (l + 1) + t + 1) * h];
                for k in 0..h {
                    let i = grow[k];
                    let f = grow[h + k];
                    let g = grow[2 * h + k];
                    let o = grow[3 * h + k];
                    let tc = cnext[k].tanh();
                    let dck = dc[k] + dh[k] * o * (1.0 - tc * tc);
                    dgates[k] = dck * g * i * (1.0 - i);
                    dgates[h + k] = dck * cprev[k] * f * (1.0 - f);
                    dgates[2 * h + k] = dck * i * (1.0 - g * g);
                    dgates[3 * h + k] = dh[k] * tc * o * (1.0 - o);
                    dc[k] = dck * f;
                }
                let dxt = &mut d_x[(r * l + t) * d..(r * l + t + 1) * d];
                simd::matmul_nt_into(&dgates, 1, fh, wx, d, dxt);
                simd::matmul_nt_into(&dgates, 1, fh, wh, h, &mut dh);
                let xrow = &x[(r * l + t) * d..(r * l + t + 1) * d];
                for k in 0..d {
                    simd::axpy(xrow[k], &dgates, &mut dwx[k * fh..(k + 1) * fh]);
                }
                let hprev = &st.hs
                    [(r * (l + 1) + t) * h..(r * (l + 1) + t + 1) * h];
                for k in 0..h {
                    simd::axpy(hprev[k], &dgates, &mut dwh[k * fh..(k + 1) * fh]);
                }
                simd::axpy(1.0, &dgates, &mut db);
            }
        }
        LmGrads { d_x, dwx, dwh, db, dproj }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::logsumexp;
    use crate::rng::Rng;
    use crate::softmax::{full_softmax_loss, sampled_softmax_loss};

    fn close(got: f32, want: f64, rel: f64, abs: f64, ctx: &str) {
        let diff = (got as f64 - want).abs();
        assert!(
            diff <= rel * want.abs() + abs,
            "{ctx}: got {got}, want {want} (diff {diff:.3e})"
        );
    }

    fn randv(rng: &mut Rng, len: usize, scale: f64) -> Vec<f32> {
        (0..len).map(|_| (rng.gaussian() * scale) as f32).collect()
    }

    fn to64(v: &[f32]) -> Vec<f64> {
        v.iter().map(|&x| x as f64).collect()
    }

    /// Straight-line f64 reference of the fused sampled loss (normalize
    /// with the ε clamp → logits → adjust → mask → logsumexp → mean).
    #[allow(clippy::too_many_arguments)]
    fn ref_sampled_loss(
        q: &[f64],
        tgt: &[f64],
        neg: &[f64],
        adjust: &[f64],
        mask: &[f32],
        b: usize,
        d: usize,
        m: usize,
        tau: f64,
        absolute: bool,
    ) -> f64 {
        let eps = NORM_EPS as f64;
        let nrm = |x: &[f64]| -> Vec<f64> {
            let n = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(eps);
            x.iter().map(|v| v / n).collect()
        };
        let dot = |a: &[f64], c: &[f64]| -> f64 {
            a.iter().zip(c).map(|(x, y)| x * y).sum()
        };
        let mut total = 0.0;
        for r in 0..b {
            let qh = nrm(&q[r * d..(r + 1) * d]);
            let th = nrm(&tgt[r * d..(r + 1) * d]);
            let ot_raw = tau * dot(&qh, &th);
            let ot = if absolute { ot_raw.abs() } else { ot_raw };
            let mut row = vec![ot];
            for j in 0..m {
                if mask[r * m + j] == 0.0 {
                    continue;
                }
                let ch = nrm(&neg[j * d..(j + 1) * d]);
                let v = tau * dot(&qh, &ch) - adjust[j];
                row.push(if absolute { v.abs() } else { v });
            }
            total += logsumexp(&row) - ot;
        }
        total / b as f64
    }

    struct Case {
        b: usize,
        d: usize,
        m: usize,
        tau: f32,
        q: Matrix,
        tgt: Vec<f32>,
        neg: Vec<f32>,
        adjust: Vec<f32>,
        mask: Vec<f32>,
    }

    fn make_case(seed: u64, b: usize, d: usize, m: usize) -> Case {
        let mut rng = Rng::seeded(seed);
        let q = Matrix::from_vec(b, d, randv(&mut rng, b * d, 0.9));
        let tgt = randv(&mut rng, b * d, 0.9);
        let neg = randv(&mut rng, m * d, 0.9);
        let adjust: Vec<f32> = (0..m)
            .map(|_| ((m as f64) * (0.05 + 0.9 * rng.f64_open())).ln() as f32)
            .collect();
        let mask = vec![1.0f32; b * m];
        Case { b, d, m, tau: 0.8, q, tgt, neg, adjust, mask }
    }

    /// Run the fused kernel + the f64 reference + central finite
    /// differences over every input coordinate; assert rel ≤ 1e-4.
    fn check_fused_against_fd(case: &Case, absolute: bool, ctx: &str) {
        let (b, d, m) = (case.b, case.d, case.m);
        let mut q = case.q.clone();
        let mut tgt = case.tgt.clone();
        let mut neg = case.neg.clone();
        let mut fused = FusedLoss::new(4);
        let loss = fused.run(
            &mut q,
            &mut tgt,
            &mut neg,
            &case.adjust,
            &case.mask,
            case.tau,
            absolute,
        );
        let q64 = to64(case.q.data());
        let t64 = to64(&case.tgt);
        let n64 = to64(&case.neg);
        let a64 = to64(&case.adjust);
        let tau = case.tau as f64;
        let f = |q: &[f64], t: &[f64], n: &[f64]| {
            ref_sampled_loss(
                q, t, n, &a64, &case.mask, b, d, m, tau, absolute,
            )
        };
        close(loss, f(&q64, &t64, &n64), 1e-5, 1e-7, &format!("{ctx} loss"));
        let eps = 1e-6;
        let fd = |v: &mut Vec<f64>,
                  i: usize,
                  f: &dyn Fn(&[f64]) -> f64|
         -> f64 {
            let save = v[i];
            v[i] = save + eps;
            let lp = f(v);
            v[i] = save - eps;
            let lm = f(v);
            v[i] = save;
            (lp - lm) / (2.0 * eps)
        };
        let mut q64m = q64.clone();
        for i in 0..b * d {
            let g = fd(&mut q64m, i, &|v| f(v, &t64, &n64));
            close(fused.d_q[i], g, 1e-4, 5e-6, &format!("{ctx} d_q[{i}]"));
        }
        let mut t64m = t64.clone();
        for i in 0..b * d {
            let g = fd(&mut t64m, i, &|v| f(&q64, v, &n64));
            close(fused.d_tgt[i], g, 1e-4, 5e-6, &format!("{ctx} d_tgt[{i}]"));
        }
        let mut n64m = n64.clone();
        for i in 0..m * d {
            let g = fd(&mut n64m, i, &|v| f(&q64, &t64, v));
            close(fused.d_neg[i], g, 1e-4, 5e-6, &format!("{ctx} d_neg[{i}]"));
        }
    }

    #[test]
    fn fused_matches_f64_finite_differences() {
        let case = make_case(11, 3, 7, 5);
        check_fused_against_fd(&case, false, "plain");
    }

    #[test]
    fn fused_matches_fd_with_mask_and_absolute() {
        let mut case = make_case(13, 3, 6, 5);
        case.mask[2] = 0.0; // row 0, col 2
        case.mask[case.m + 4] = 0.0; // row 1, col 4
        check_fused_against_fd(&case, false, "masked");
        let case = make_case(17, 2, 5, 4);
        check_fused_against_fd(&case, true, "absolute");
    }

    #[test]
    fn fused_loss_matches_sampled_softmax_oracle() {
        // Same math as the f64 oracle: q_j = exp(adjust_j)/m, per-row
        // loss from normalized f64 logits, batch mean.
        let case = make_case(19, 4, 8, 6);
        let (b, d, m) = (case.b, case.d, case.m);
        let mut q = case.q.clone();
        let mut tgt = case.tgt.clone();
        let mut neg = case.neg.clone();
        let mut fused = FusedLoss::new(4);
        let loss = fused.run(
            &mut q,
            &mut tgt,
            &mut neg,
            &case.adjust,
            &case.mask,
            case.tau,
            false,
        );
        let eps = NORM_EPS as f64;
        let nrm = |x: &[f64]| -> Vec<f64> {
            let n = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(eps);
            x.iter().map(|v| v / n).collect()
        };
        let q64 = to64(case.q.data());
        let t64 = to64(&case.tgt);
        let n64 = to64(&case.neg);
        let tau = case.tau as f64;
        let qs: Vec<f64> = case
            .adjust
            .iter()
            .map(|&a| (a as f64).exp() / m as f64)
            .collect();
        let mut want = 0.0;
        for r in 0..b {
            let qh = nrm(&q64[r * d..(r + 1) * d]);
            let th = nrm(&t64[r * d..(r + 1) * d]);
            let ot: f64 =
                tau * qh.iter().zip(&th).map(|(a, c)| a * c).sum::<f64>();
            let negl: Vec<f64> = (0..m)
                .map(|j| {
                    let ch = nrm(&n64[j * d..(j + 1) * d]);
                    tau * qh.iter().zip(&ch).map(|(a, c)| a * c).sum::<f64>()
                })
                .collect();
            want += sampled_softmax_loss(ot, &negl, &qs).loss;
        }
        close(loss, want / b as f64, 1e-5, 1e-7, "oracle loss");
    }

    #[test]
    fn fused_matches_composed_pipeline() {
        for &absolute in &[false, true] {
            let mut case = make_case(23, 5, 9, 7);
            case.mask[3] = 0.0;
            let mut q = case.q.clone();
            let mut tgt = case.tgt.clone();
            let mut neg = case.neg.clone();
            let mut fused = FusedLoss::new(3);
            let loss = fused.run(
                &mut q,
                &mut tgt,
                &mut neg,
                &case.adjust,
                &case.mask,
                case.tau,
                absolute,
            );
            let out = composed::sampled_loss_grad(
                &case.q,
                &case.tgt,
                &case.neg,
                &case.adjust,
                &case.mask,
                case.tau,
                absolute,
            );
            close(loss, out.loss as f64, 1e-5, 1e-6, "composed loss");
            for (i, (&a, &w)) in
                fused.d_q.iter().zip(&out.d_q).enumerate()
            {
                close(a, w as f64, 1e-4, 1e-6, &format!("composed d_q[{i}]"));
            }
            for (i, (&a, &w)) in
                fused.d_tgt.iter().zip(&out.d_tgt).enumerate()
            {
                close(a, w as f64, 1e-4, 1e-6, &format!("composed d_tgt[{i}]"));
            }
            for (i, (&a, &w)) in
                fused.d_neg.iter().zip(&out.d_neg).enumerate()
            {
                close(a, w as f64, 1e-4, 1e-6, &format!("composed d_neg[{i}]"));
            }
        }
    }

    #[test]
    fn fully_masked_class_gets_zero_grad() {
        let mut case = make_case(29, 3, 5, 4);
        for r in 0..case.b {
            case.mask[r * case.m + 1] = 0.0;
        }
        let mut q = case.q.clone();
        let mut tgt = case.tgt.clone();
        let mut neg = case.neg.clone();
        let mut fused = FusedLoss::new(2);
        fused.run(
            &mut q,
            &mut tgt,
            &mut neg,
            &case.adjust,
            &case.mask,
            case.tau,
            false,
        );
        let d = case.d;
        assert!(
            fused.d_neg[d..2 * d].iter().all(|&g| g == 0.0),
            "masked-everywhere class must get zero grad"
        );
    }

    #[test]
    fn zero_query_row_stays_finite() {
        let mut case = make_case(31, 3, 5, 4);
        case.q.row_mut(0).fill(0.0);
        let mut q = case.q.clone();
        let mut tgt = case.tgt.clone();
        let mut neg = case.neg.clone();
        let mut fused = FusedLoss::new(2);
        let loss = fused.run(
            &mut q,
            &mut tgt,
            &mut neg,
            &case.adjust,
            &case.mask,
            case.tau,
            false,
        );
        assert!(loss.is_finite(), "loss with a zero row must be finite");
        assert!(fused.d_q.iter().all(|g| g.is_finite()));
        assert!(fused.d_tgt.iter().all(|g| g.is_finite()));
        assert!(fused.d_neg.iter().all(|g| g.is_finite()));
    }

    /// f64 LSTM reference of `J = Σ u ∘ v` for finite differences.
    #[allow(clippy::too_many_arguments)]
    fn ref_lm_j(
        x: &[f64],
        b: usize,
        l: usize,
        d: usize,
        h: usize,
        wx: &[f64],
        wh: &[f64],
        bias: &[f64],
        proj: &[f64],
        v: &[f64],
    ) -> f64 {
        let fh = 4 * h;
        let sg = |x: f64| 1.0 / (1.0 + (-x).exp());
        let mut total = 0.0;
        for r in 0..b {
            let mut hv = vec![0.0f64; h];
            let mut cv = vec![0.0f64; h];
            for t in 0..l {
                let xt = &x[(r * l + t) * d..(r * l + t + 1) * d];
                let mut g = vec![0.0f64; fh];
                for (j, gj) in g.iter_mut().enumerate() {
                    let mut s = bias[j];
                    for k in 0..d {
                        s += xt[k] * wx[k * fh + j];
                    }
                    for k in 0..h {
                        s += hv[k] * wh[k * fh + j];
                    }
                    *gj = s;
                }
                for k in 0..h {
                    let i = sg(g[k]);
                    let f = sg(g[h + k]);
                    let gg = g[2 * h + k].tanh();
                    let o = sg(g[3 * h + k]);
                    cv[k] = f * cv[k] + i * gg;
                    hv[k] = o * cv[k].tanh();
                }
            }
            for j in 0..d {
                let mut s = 0.0;
                for k in 0..h {
                    s += hv[k] * proj[k * d + j];
                }
                total += s * v[r * d + j];
            }
        }
        total
    }

    #[test]
    fn lm_step_matches_composed_and_f64_fd() {
        let (b, l, d, h) = (5, 3, 6, 4);
        let fh = 4 * h;
        let mut rng = Rng::seeded(37);
        let xsrc = randv(&mut rng, b * l * d, 0.7);
        let wx = randv(&mut rng, d * fh, 0.4);
        let wh = randv(&mut rng, h * fh, 0.4);
        let bias = randv(&mut rng, fh, 0.2);
        let proj = randv(&mut rng, h * d, 0.4);
        let du = randv(&mut rng, b * d, 0.8);
        let ids: Vec<u32> = (0..(b * l) as u32).collect();

        let mut lm = LmStep::new(3);
        lm.begin(b, l, d, h);
        lm.load_rows(&xsrc, &ids);
        lm.forward(&wx, &wh, &bias, &proj);
        lm.backward(&wx, &wh, &proj, &du);

        let st = composed::lm_forward(&xsrc, b, l, d, h, &wx, &wh, &bias, &proj);
        for i in 0..b * d {
            close(
                lm.u.data()[i],
                st.u.data()[i] as f64,
                1e-4,
                1e-5,
                &format!("u[{i}]"),
            );
        }
        let gr = composed::lm_backward(
            &st, &xsrc, b, l, d, h, &wx, &wh, &proj, &du,
        );
        for r in 0..b {
            for t in 0..l {
                let a = lm.d_x_row(r, t);
                let w = &gr.d_x[(r * l + t) * d..(r * l + t + 1) * d];
                for k in 0..d {
                    close(
                        a[k],
                        w[k] as f64,
                        1e-4,
                        1e-5,
                        &format!("d_x[{r},{t},{k}]"),
                    );
                }
            }
        }
        for (name, got, want) in [
            ("dwx", &lm.dwx, &gr.dwx),
            ("dwh", &lm.dwh, &gr.dwh),
            ("db", &lm.db, &gr.db),
            ("dproj", &lm.dproj, &gr.dproj),
        ] {
            assert_eq!(got.len(), want.len());
            for i in 0..got.len() {
                close(
                    got[i],
                    want[i] as f64,
                    1e-4,
                    1e-5,
                    &format!("{name}[{i}]"),
                );
            }
        }

        // f64 finite differences on J = Σ u∘v (v = du): validates the
        // BPTT calculus independently of both implementations.
        let x64 = to64(&xsrc);
        let wx64 = to64(&wx);
        let wh64 = to64(&wh);
        let b64 = to64(&bias);
        let p64 = to64(&proj);
        let v64 = to64(&du);
        let jf = |x: &[f64], wx: &[f64], wh: &[f64], bb: &[f64], pp: &[f64]| {
            ref_lm_j(x, b, l, d, h, wx, wh, bb, pp, &v64)
        };
        let eps = 1e-6;
        let fd_check = |vsrc: &[f64],
                            idx: usize,
                            which: usize,
                            got: f32,
                            name: &str| {
            let mut v = vsrc.to_vec();
            let save = v[idx];
            v[idx] = save + eps;
            let lp = match which {
                0 => jf(&v, &wx64, &wh64, &b64, &p64),
                1 => jf(&x64, &v, &wh64, &b64, &p64),
                2 => jf(&x64, &wx64, &v, &b64, &p64),
                3 => jf(&x64, &wx64, &wh64, &v, &p64),
                _ => jf(&x64, &wx64, &wh64, &b64, &v),
            };
            v[idx] = save - eps;
            let lm_ = match which {
                0 => jf(&v, &wx64, &wh64, &b64, &p64),
                1 => jf(&x64, &v, &wh64, &b64, &p64),
                2 => jf(&x64, &wx64, &v, &b64, &p64),
                3 => jf(&x64, &wx64, &wh64, &v, &p64),
                _ => jf(&x64, &wx64, &wh64, &b64, &v),
            };
            let g = (lp - lm_) / (2.0 * eps);
            close(got, g, 1e-4, 1e-5, name);
        };
        for i in (0..b * l * d).step_by(13) {
            let (rt, k) = (i / d, i % d);
            let got = lm.d_x_row(rt / l, rt % l)[k];
            fd_check(&x64, i, 0, got, &format!("fd d_x[{i}]"));
        }
        for i in (0..d * fh).step_by(11) {
            fd_check(&wx64, i, 1, lm.dwx[i], &format!("fd dwx[{i}]"));
        }
        for i in (0..h * fh).step_by(7) {
            fd_check(&wh64, i, 2, lm.dwh[i], &format!("fd dwh[{i}]"));
        }
        for i in 0..fh {
            fd_check(&b64, i, 3, lm.db[i], &format!("fd db[{i}]"));
        }
        for i in (0..h * d).step_by(5) {
            fd_check(&p64, i, 4, lm.dproj[i], &format!("fd dproj[{i}]"));
        }
    }

    /// f64 reference of the full-softmax mean loss (ε-clamped
    /// normalization optional), for oracle + FD checks.
    fn ref_full_loss(
        q: &[f64],
        cls: &[f64],
        targets: &[u32],
        b: usize,
        n: usize,
        d: usize,
        tau: f64,
        normalize: bool,
    ) -> f64 {
        let eps = NORM_EPS as f64;
        let nrm = |x: &[f64]| -> Vec<f64> {
            if !normalize {
                return x.to_vec();
            }
            let nn = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(eps);
            x.iter().map(|v| v / nn).collect()
        };
        let ch: Vec<Vec<f64>> =
            (0..n).map(|j| nrm(&cls[j * d..(j + 1) * d])).collect();
        let mut total = 0.0;
        for r in 0..b {
            let qh = nrm(&q[r * d..(r + 1) * d]);
            let logits: Vec<f64> = (0..n)
                .map(|j| {
                    tau * qh.iter().zip(&ch[j]).map(|(a, c)| a * c).sum::<f64>()
                })
                .collect();
            total += full_softmax_loss(&logits, targets[r] as usize).0;
        }
        total / b as f64
    }

    #[test]
    fn full_loss_matches_oracle_and_fd() {
        let (b, n, d) = (3, 9, 5);
        let tau = 0.7f32;
        let mut rng = Rng::seeded(41);
        let cls = randv(&mut rng, n * d, 0.8);
        let qsrc = randv(&mut rng, b * d, 0.8);
        let targets: Vec<u32> =
            (0..b).map(|_| rng.index(n) as u32).collect();

        let mut full = FullLoss::new(4);
        full.prepare_classes(&cls, n, d, true);
        let mut q = Matrix::from_vec(b, d, qsrc.clone());
        let loss = full.forward(&mut q, &targets, tau);
        let q64 = to64(&qsrc);
        let c64 = to64(&cls);
        let want =
            ref_full_loss(&q64, &c64, &targets, b, n, d, tau as f64, true);
        close(loss, want, 1e-5, 1e-7, "full loss");

        full.backward(&q, &targets, tau);
        let eps = 1e-6;
        let mut qm = q64.clone();
        for i in 0..b * d {
            let save = qm[i];
            qm[i] = save + eps;
            let lp =
                ref_full_loss(&qm, &c64, &targets, b, n, d, tau as f64, true);
            qm[i] = save - eps;
            let lm =
                ref_full_loss(&qm, &c64, &targets, b, n, d, tau as f64, true);
            qm[i] = save;
            let g = (lp - lm) / (2.0 * eps);
            close(full.d_q[i], g, 1e-4, 5e-6, &format!("full d_q[{i}]"));
        }
        let mut cm = c64.clone();
        for i in 0..n * d {
            let save = cm[i];
            cm[i] = save + eps;
            let lp =
                ref_full_loss(&q64, &cm, &targets, b, n, d, tau as f64, true);
            cm[i] = save - eps;
            let lm =
                ref_full_loss(&q64, &cm, &targets, b, n, d, tau as f64, true);
            cm[i] = save;
            let g = (lp - lm) / (2.0 * eps);
            close(full.d_cls[i], g, 1e-4, 5e-6, &format!("full d_cls[{i}]"));
        }

        // Unnormalized ablation variant.
        let mut full_u = FullLoss::new(4);
        full_u.prepare_classes(&cls, n, d, false);
        let mut q2 = Matrix::from_vec(b, d, qsrc.clone());
        let loss_u = full_u.forward(&mut q2, &targets, tau);
        let want_u =
            ref_full_loss(&q64, &c64, &targets, b, n, d, tau as f64, false);
        close(loss_u, want_u, 1e-5, 1e-7, "unnorm full loss");
    }

    #[test]
    fn full_scores_rank_by_cosine() {
        let (b, n, d) = (2, 6, 4);
        let mut rng = Rng::seeded(43);
        let cls = randv(&mut rng, n * d, 0.8);
        let qsrc = randv(&mut rng, b * d, 0.8);
        let mut full = FullLoss::new(3);
        full.prepare_classes(&cls, n, d, true);
        let mut q = Matrix::from_vec(b, d, qsrc.clone());
        let mut scores = vec![0.0f32; b * n];
        full.scores_into(&mut q, &mut scores);
        let eps = NORM_EPS as f64;
        let nrm = |x: &[f64]| -> Vec<f64> {
            let nn = x.iter().map(|v| v * v).sum::<f64>().sqrt().max(eps);
            x.iter().map(|v| v / nn).collect()
        };
        let q64 = to64(&qsrc);
        let c64 = to64(&cls);
        for r in 0..b {
            let qh = nrm(&q64[r * d..(r + 1) * d]);
            for j in 0..n {
                let ch = nrm(&c64[j * d..(j + 1) * d]);
                let want: f64 =
                    qh.iter().zip(&ch).map(|(a, c)| a * c).sum();
                close(
                    scores[r * n + j],
                    want,
                    1e-4,
                    1e-5,
                    &format!("score[{r},{j}]"),
                );
            }
        }
    }

    #[test]
    fn xc_step_forward_and_feat_grad() {
        let d = 3;
        let w = vec![
            1.0f32, 2.0, 3.0, // row 0
            -1.0, 0.5, 0.0, // row 1
            0.0, 1.0, -2.0, // row 2
            4.0, 0.0, 1.0, // row 3
        ];
        let feats = vec![0u32, 2, 1, 3];
        let vals = vec![0.5f32, 2.0, 1.0, -1.0];
        let mut xc = XcStep::new(2);
        xc.forward(&w, d, &feats, &vals, 2, 2);
        // row 0: 0.5·w0 + 2·w2 ; row 1: 1·w1 − 1·w3
        let want0 = [0.5, 3.0, -2.5];
        let want1 = [-5.0, 0.5, -1.0];
        for k in 0..d {
            close(xc.u.get(0, k), want0[k], 1e-6, 1e-6, "xc u0");
            close(xc.u.get(1, k), want1[k], 1e-6, 1e-6, "xc u1");
        }
        let du = vec![1.0f32, -1.0, 2.0, 0.5, 0.5, 0.0];
        xc.feat_grad(&du, &vals, 2, 2, d);
        // d_feat[(r, j)] = vals[r, j] · du_r
        let want = [
            [0.5, -0.5, 1.0],
            [2.0, -2.0, 4.0],
            [0.5, 0.5, 0.0],
            [-0.5, -0.5, -0.0],
        ];
        for (slot, wrow) in want.iter().enumerate() {
            for k in 0..d {
                close(
                    xc.d_feat[slot * d + k],
                    wrow[k],
                    1e-6,
                    1e-6,
                    "xc d_feat",
                );
            }
        }
    }

    #[test]
    fn scratch_growth_counters_are_flat_after_warmup() {
        let case = make_case(47, 6, 8, 10);
        let mut fused = FusedLoss::new(3);
        let run = |f: &mut FusedLoss| {
            let mut q = case.q.clone();
            let mut tgt = case.tgt.clone();
            let mut neg = case.neg.clone();
            f.run(
                &mut q,
                &mut tgt,
                &mut neg,
                &case.adjust,
                &case.mask,
                case.tau,
                false,
            );
        };
        run(&mut fused);
        let warm = fused.growths();
        for _ in 0..3 {
            run(&mut fused);
        }
        assert_eq!(fused.growths(), warm, "FusedLoss must not regrow");

        let (b, l, d, h) = (4, 3, 5, 4);
        let mut rng = Rng::seeded(49);
        let xsrc = randv(&mut rng, b * l * d, 0.5);
        let wx = randv(&mut rng, d * 4 * h, 0.3);
        let wh = randv(&mut rng, h * 4 * h, 0.3);
        let bias = randv(&mut rng, 4 * h, 0.1);
        let proj = randv(&mut rng, h * d, 0.3);
        let du = randv(&mut rng, b * d, 0.5);
        let ids: Vec<u32> = (0..(b * l) as u32).collect();
        let mut lm = LmStep::new(3);
        let run_lm = |s: &mut LmStep| {
            s.begin(b, l, d, h);
            s.load_rows(&xsrc, &ids);
            s.forward(&wx, &wh, &bias, &proj);
            s.backward(&wx, &wh, &proj, &du);
        };
        run_lm(&mut lm);
        let warm = lm.growths();
        for _ in 0..3 {
            run_lm(&mut lm);
        }
        assert_eq!(lm.growths(), warm, "LmStep must not regrow");

        let (n, bq) = (7, 3);
        let cls = randv(&mut rng, n * d, 0.5);
        let qsrc = randv(&mut rng, bq * d, 0.5);
        let targets: Vec<u32> = (0..bq).map(|_| rng.index(n) as u32).collect();
        let mut full = FullLoss::new(3);
        let run_full = |f: &mut FullLoss| {
            f.prepare_classes(&cls, n, d, true);
            let mut q = Matrix::from_vec(bq, d, qsrc.clone());
            f.forward(&mut q, &targets, 1.0);
            f.backward(&q, &targets, 1.0);
        };
        run_full(&mut full);
        let warm = full.growths();
        for _ in 0..3 {
            run_full(&mut full);
        }
        assert_eq!(full.growths(), warm, "FullLoss must not regrow");
    }

    #[test]
    fn chunk_ranges_partition_densely() {
        for &(n, w) in
            &[(1usize, 1usize), (5, 2), (10, 4), (10, 7), (3, 16), (64, 5)]
        {
            let r = chunk_ranges(n, w);
            assert!(r.len() <= w.min(n));
            assert_eq!(r[0].0, 0);
            assert_eq!(r.last().unwrap().1, n);
            for i in 1..r.len() {
                assert_eq!(r[i].0, r[i - 1].1, "ranges must be dense");
                assert!(r[i].0 < r[i].1, "ranges must be non-empty");
            }
        }
    }

    #[test]
    fn gather_rows_into_reuses_capacity() {
        let table = vec![0.0f32, 1.0, 10.0, 11.0, 20.0, 21.0];
        let mut out = Vec::new();
        let grew = gather_rows_into(&table, 2, &[2, 0], &mut out);
        assert!(grew);
        assert_eq!(out, vec![20.0, 21.0, 0.0, 1.0]);
        let grew = gather_rows_into(&table, 2, &[1, 2], &mut out);
        assert!(!grew, "same-size regather must not grow");
        assert_eq!(out, vec![10.0, 11.0, 20.0, 21.0]);
    }
}
