//! Training runtime: the backend seam between the **native** fused
//! executor and the optional **PJRT** HLO runtime.
//!
//! * [`Runtime::native`] (the default, `train.backend = native`): the
//!   trainers run one-pass fused f32 kernels from [`native`] directly
//!   over the parameter blocks — no artifacts directory, no host↔device
//!   tensor copies, scratch buffers reused across steps.
//! * [`Runtime::load`] (`train.backend = pjrt`, requires the `pjrt`
//!   cargo feature): loads the HLO-text artifacts produced by
//!   `python/compile/aot.py` (`make artifacts`) and executes them
//!   through PJRT. The feature carries no in-tree dependency — enabling
//!   it requires supplying an `xla` crate (PJRT CPU bindings) from an
//!   external source, which is why it is off by default and the tier-1
//!   gate builds without it.
//!
//! The manifest (`artifacts/manifest.json`) lists every PJRT entry
//! point with its input/output shapes and dtypes; the pjrt backend
//! validates calls against it and compiles executables lazily. The
//! native backend needs no manifest: kernel shapes come from the
//! trainer's own [`crate::config::Config`].

mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
#[cfg(feature = "pjrt")]
pub use pjrt::Executable;

use crate::config::{Config, TrainBackend};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// A host-side tensor to pass into / receive from a pjrt executable
/// (and the shape-checked interchange type of the runtime tests).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "HostTensor::f32 shape/data mismatch"
        );
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "HostTensor::i32 shape/data mismatch"
        );
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } => shape,
            HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("HostTensor: expected f32, got {}", self.dtype()),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("HostTensor: expected f32"),
        }
    }

    pub fn scalar(&self) -> f32 {
        let d = self.as_f32();
        assert_eq!(d.len(), 1, "HostTensor::scalar on non-scalar");
        d[0]
    }
}

enum BackendImpl {
    /// In-process fused kernels; no client, no artifacts.
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::PjrtRuntime),
}

/// Backend handle the trainers are built against: either the native
/// fused executor or a PJRT artifact registry + lazy compiler.
pub struct Runtime {
    backend: BackendImpl,
    dir: PathBuf,
    manifest: Manifest,
}

impl Runtime {
    /// The native fused backend (the default). Needs no artifacts
    /// directory and carries an empty manifest; trainers built against
    /// it take their kernel shapes from their own config.
    pub fn native() -> Self {
        Self {
            backend: BackendImpl::Native,
            dir: PathBuf::new(),
            manifest: Manifest::default(),
        }
    }

    /// Resolve the backend `cfg.train.backend` asks for: `native` needs
    /// nothing; `pjrt` loads the artifact manifest from `dir` (and is a
    /// clear error when this binary was built without the `pjrt`
    /// feature).
    pub fn for_train(cfg: &Config, dir: impl AsRef<Path>) -> Result<Self> {
        match cfg.train.backend {
            TrainBackend::Native => Ok(Self::native()),
            #[cfg(feature = "pjrt")]
            TrainBackend::Pjrt => Self::load(dir),
            #[cfg(not(feature = "pjrt"))]
            TrainBackend::Pjrt => {
                let _ = dir;
                bail!(
                    "train.backend = pjrt requested but this binary was \
                     built without the `pjrt` cargo feature — rebuild \
                     with `cargo build --features pjrt`, or use the \
                     default native backend (train.backend = native)"
                )
            }
        }
    }

    /// Load a PJRT artifact directory. The two failure modes get
    /// distinct, actionable messages: a *missing manifest* means the
    /// artifacts were never built (`make artifacts`), while a *present
    /// manifest* in a binary built without the `pjrt` feature means the
    /// backend itself is unavailable.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first, or use the \
                 default native backend (train.backend = native), which \
                 needs no artifacts directory",
                manifest_path.display()
            )
        })?;
        let manifest =
            Manifest::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        #[cfg(not(feature = "pjrt"))]
        {
            drop(manifest);
            bail!(
                "artifact manifest found at {} but the pjrt backend is \
                 unavailable: this binary was built without the `pjrt` \
                 cargo feature — rebuild with `cargo build --features \
                 pjrt`, or use the default native backend \
                 (train.backend = native)",
                manifest_path.display()
            )
        }
        #[cfg(feature = "pjrt")]
        {
            let rt = pjrt::PjrtRuntime::new()?;
            Ok(Self { backend: BackendImpl::Pjrt(rt), dir, manifest })
        }
    }

    /// Default artifact directory (`$RFSM_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var("RFSM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Which backend this runtime executes on.
    pub fn backend(&self) -> TrainBackend {
        match &self.backend {
            BackendImpl::Native => TrainBackend::Native,
            #[cfg(feature = "pjrt")]
            BackendImpl::Pjrt(_) => TrainBackend::Pjrt,
        }
    }

    pub fn is_native(&self) -> bool {
        matches!(self.backend, BackendImpl::Native)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The artifact directory this runtime was loaded from (empty for
    /// the native backend, which has none).
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            BackendImpl::Native => {
                format!("native-cpu/{}", crate::linalg::simd::tier_name())
            }
            #[cfg(feature = "pjrt")]
            BackendImpl::Pjrt(rt) => rt.platform(),
        }
    }

    /// Whether an entry point exists in the manifest (always false on
    /// the native backend — it has no artifacts).
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    /// Get (compiling + caching on first use) a pjrt executable by name.
    #[cfg(feature = "pjrt")]
    pub fn get(&self, name: &str) -> Result<std::rc::Rc<Executable>> {
        match &self.backend {
            BackendImpl::Pjrt(rt) => rt.get(&self.dir, &self.manifest, name),
            BackendImpl::Native => bail!(
                "artifact '{name}' requested on the native backend — \
                 executables exist only under train.backend = pjrt"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), "f32");
        let s = HostTensor::scalar_f32(4.0);
        assert_eq!(s.scalar(), 4.0);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn host_tensor_rejects_bad_shape() {
        HostTensor::f32(&[2, 2], vec![0.0; 5]);
    }

    #[test]
    fn missing_manifest_is_friendly_error() {
        let msg = match Runtime::load("/nonexistent/dir") {
            Ok(_) => panic!("load of missing dir must fail"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
        // The missing-manifest path must also point at the native
        // escape hatch — it needs no artifacts at all.
        assert!(msg.contains("native"), "no native hint: {msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn present_manifest_without_feature_is_backend_unavailable() {
        // A well-formed manifest on disk but no `pjrt` feature in the
        // binary: the error must say the *backend* is missing, not that
        // the artifacts are.
        let dir = std::env::temp_dir().join("rfsm_runtime_feature_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"artifacts": {}}"#)
            .unwrap();
        let msg = match Runtime::load(&dir) {
            Ok(_) => panic!("load without pjrt feature must fail"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("pjrt"), "no feature hint: {msg}");
        assert!(!msg.contains("make artifacts"), "wrong failure mode: {msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn for_train_rejects_pjrt_without_feature() {
        let mut cfg = Config::default();
        cfg.set("train.backend", "pjrt").unwrap();
        let msg = match Runtime::for_train(&cfg, "/nonexistent/dir") {
            Ok(_) => panic!("pjrt backend without the feature must fail"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("--features pjrt"), "no rebuild hint: {msg}");
    }

    #[test]
    fn native_runtime_reports_backend() {
        let rt = Runtime::native();
        assert!(rt.is_native());
        assert_eq!(rt.backend(), TrainBackend::Native);
        assert!(rt.platform().starts_with("native-cpu/"));
        assert!(rt.manifest().is_empty());
        assert!(!rt.has("anything"));
        let cfg = Config::default();
        let rt = Runtime::for_train(&cfg, "/nonexistent/dir").unwrap();
        assert!(rt.is_native(), "default backend must be native");
    }
}
