//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and executes them from the
//! Rust hot path. Python never runs here.
//!
//! Interchange is **HLO text** — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §1).
//!
//! The manifest (`artifacts/manifest.json`) lists every entry point with
//! its input/output shapes and dtypes; [`Runtime`] validates calls against
//! it and compiles executables lazily (first use) with caching.

mod manifest;

pub use manifest::{ArtifactMeta, Manifest, TensorMeta};

use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A host-side tensor to pass into / receive from an executable.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "HostTensor::f32 shape/data mismatch"
        );
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "HostTensor::i32 shape/data mismatch"
        );
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } => shape,
            HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("HostTensor: expected f32, got {}", self.dtype()),
        }
    }

    pub fn into_f32(self) -> Vec<f32> {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("HostTensor: expected f32"),
        }
    }

    pub fn scalar(&self) -> f32 {
        let d = self.as_f32();
        assert_eq!(d.len(), 1, "HostTensor::scalar on non-scalar");
        d[0]
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => {
                xla::Literal::vec1(data).reshape(&dims)?
            }
            HostTensor::I32 { data, .. } => {
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> =
            shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl Executable {
    /// Execute with shape/dtype validation against the manifest.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, m)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.shape() != m.shape.as_slice() || t.dtype() != m.dtype {
                bail!(
                    "{}: input {i} ('{}') expects {}{:?}, got {}{:?}",
                    self.meta.name,
                    m.name,
                    m.dtype,
                    m.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out_lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = out_lit.to_tuple()?;
        let mut outs = Vec::with_capacity(parts.len());
        for p in &parts {
            outs.push(HostTensor::from_literal(p)?);
        }
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: manifest declares {} outputs, executable returned {}",
                self.meta.name,
                self.meta.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Artifact registry + lazy compiler. One PJRT CPU client per runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Runtime {
    /// Load the manifest from an artifact directory (does not compile yet).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text)
            .map_err(|e| anyhow!("manifest parse: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifact directory (`$RFSM_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var("RFSM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Whether an entry point exists in the manifest.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    /// Get (compiling + caching on first use) an executable by name.
    pub fn get(&self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| {
                anyhow!(
                    "unknown artifact '{name}'; manifest has: {}",
                    self.manifest.names().join(", ")
                )
            })?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let executable = std::rc::Rc::new(Executable { exe, meta });
        self.cache.borrow_mut().insert(name.to_string(), executable.clone());
        Ok(executable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), "f32");
        let s = HostTensor::scalar_f32(4.0);
        assert_eq!(s.scalar(), 4.0);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn host_tensor_rejects_bad_shape() {
        HostTensor::f32(&[2, 2], vec![0.0; 5]);
    }

    #[test]
    fn missing_manifest_is_friendly_error() {
        let msg = match Runtime::load("/nonexistent/dir") {
            Ok(_) => panic!("load of missing dir must fail"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }
}
