//! The PJRT execution backend (compiled only under the `pjrt` cargo
//! feature). Loads HLO-text artifacts, compiles them lazily through a
//! PJRT CPU client, and runs them with manifest shape/dtype validation.
//!
//! Interchange is **HLO text** — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §1).
//!
//! The `xla` crate (PJRT CPU bindings) is deliberately not an in-tree
//! dependency: building with `--features pjrt` requires patching one in,
//! which keeps the default tier-1 build free of the phantom dependency.

use super::manifest::{ArtifactMeta, Manifest};
use super::HostTensor;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        HostTensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
    };
    Ok(lit)
}

fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => {
            Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
        }
        xla::ElementType::S32 => {
            Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
        }
        other => bail!("unsupported output element type {other:?}"),
    }
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl Executable {
    /// Execute with shape/dtype validation against the manifest.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, m)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.shape() != m.shape.as_slice() || t.dtype() != m.dtype {
                bail!(
                    "{}: input {i} ('{}') expects {}{:?}, got {}{:?}",
                    self.meta.name,
                    m.name,
                    m.dtype,
                    m.shape,
                    t.dtype(),
                    t.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let out_lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = out_lit.to_tuple()?;
        let mut outs = Vec::with_capacity(parts.len());
        for p in &parts {
            outs.push(from_literal(p)?);
        }
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: manifest declares {} outputs, executable returned {}",
                self.meta.name,
                self.meta.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// One PJRT CPU client plus the lazy executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl PjrtRuntime {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling + caching on first use) an executable by name.
    pub fn get(
        &self,
        dir: &Path,
        manifest: &Manifest,
        name: &str,
    ) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = manifest
            .get(name)
            .ok_or_else(|| {
                anyhow!(
                    "unknown artifact '{name}'; manifest has: {}",
                    manifest.names().join(", ")
                )
            })?
            .clone();
        let path = dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let executable = Rc::new(Executable { exe, meta });
        self.cache.borrow_mut().insert(name.to_string(), executable.clone());
        Ok(executable)
    }
}
