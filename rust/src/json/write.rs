//! JSON serialization: compact (via `Display`) and pretty-printed.

use super::Json;
use std::fmt::{self, Write as _};

pub(super) fn write_compact(j: &Json, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    let mut buf = String::new();
    write_value(j, &mut buf, None, 0);
    f.write_str(&buf)
}

/// Pretty-print with 2-space indentation.
pub fn to_string_pretty(j: &Json) -> String {
    let mut buf = String::new();
    write_value(j, &mut buf, Some(2), 0);
    buf.push('\n');
    buf
}

fn write_value(j: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match j {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_number(*n, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, v)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(v, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            // Shortest round-trippable representation Rust offers.
            let _ = write!(out, "{n}");
        }
    } else {
        // JSON has no NaN/Inf; emit null (documented lossy behaviour for
        // metric dumps that hit numerical edge cases).
        out.push_str("null");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{parse, Json};
    use super::*;

    #[test]
    fn compact_round_trip() {
        let j = parse(r#"{"b":[1,2.5,-3e2],"a":"x\ny","n":null,"t":true}"#).unwrap();
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn pretty_round_trip() {
        let j = parse(r#"{"outer":{"inner":[1,{"deep":[]}]}}"#).unwrap();
        let s = to_string_pretty(&j);
        assert!(s.contains("\n  "));
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn integers_without_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn control_chars_escaped() {
        let j = Json::Str("\u{0001}".into());
        assert_eq!(j.to_string(), "\"\\u0001\"");
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }
}
