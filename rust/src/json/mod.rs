//! Minimal JSON substrate (parser + writer), built from scratch because no
//! serde is available offline. Used for:
//!
//! * reading `artifacts/manifest.json` written by the python AOT pipeline,
//! * the [`crate::config`] file format,
//! * metric / experiment-result dumps.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are held as f64; integer accessors
//! check representability.

mod parse;
mod write;

pub use parse::{parse, ParseError};
pub use write::to_string_pretty;

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap for deterministic iteration / serialization order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 2f64.powi(53) => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Path access: `j.at(&["model", "dims", "0"])` — numeric path
    /// components index into arrays.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(o) => o.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write::write_compact(self, f)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let j = parse(r#"{"a": 1, "b": [true, null, "x"], "c": {"d": 2.5}}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(j.at(&["b", "0"]).unwrap().as_bool(), Some(true));
        assert_eq!(j.at(&["b", "2"]).unwrap().as_str(), Some("x"));
        assert_eq!(j.at(&["c", "d"]).unwrap().as_f64(), Some(2.5));
        assert_eq!(j.at(&["c", "missing"]), None);
        assert_eq!(j.at(&["b", "9"]), None);
    }

    #[test]
    fn i64_rejects_fractional() {
        assert_eq!(Json::Num(1.5).as_i64(), None);
        assert_eq!(Json::Num(3.0).as_i64(), Some(3));
    }

    #[test]
    fn round_trip_display() {
        let src = r#"{"k":[1,2.5,"s\n",false,null]}"#;
        let j = parse(src).unwrap();
        let again = parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }
}
