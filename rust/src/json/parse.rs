//! Recursive-descent JSON parser. Positions are tracked for error
//! messages; input must be UTF-8 (we operate on `&str` bytes and only
//! split at ASCII boundaries, copying string contents verbatim).

use super::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Parse failure with byte offset and description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(src: &str) -> Result<Json, ParseError> {
    let mut p = Parser { src: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.src.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError { offset: self.pos, message: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            match b {
                b' ' | b'\t' | b'\n' | b'\r' => self.pos += 1,
                _ => break,
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the full sequence verbatim.
                    let len = utf8_len(b)
                        .ok_or_else(|| self.err("invalid utf-8 lead byte"))?;
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.src.len() {
                        return Err(self.err("truncated utf-8 sequence"));
                    }
                    let s = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("invalid utf-8 sequence"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part.
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        // Fraction.
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // Exponent.
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("unparseable number"))
    }
}

fn utf8_len(lead: u8) -> Option<usize> {
    match lead {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"c\"A""#).unwrap(),
            Json::Str("a\nb\t\"c\"A".into())
        );
    }

    #[test]
    fn surrogate_pair() {
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn nested() {
        let j = parse(r#"{"a": [1, {"b": [2, 3]}], "c": {}}"#).unwrap();
        assert_eq!(j.at(&["a", "1", "b", "1"]).unwrap().as_i64(), Some(3));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("01").is_err() || parse("01").is_ok() == false);
        assert!(parse("\"\\x\"").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let j = parse(" \n\t{ \"a\" :\r 1 } \n").unwrap();
        assert_eq!(j.get("a").unwrap().as_i64(), Some(1));
    }
}
