//! `rfsoftmax` — CLI entrypoint for the RF-softmax training framework.
//!
//! ```text
//! rfsoftmax train --prefix ptb --sampler.kind rff --train.steps 2000
//! rfsoftmax train --train.backend pjrt --artifacts artifacts  # HLO path
//! rfsoftmax info                       # backend + compiled artifacts
//! rfsoftmax sample --sampler.kind rff  # standalone sampling demo
//! rfsoftmax bias --sampler.kind uniform
//! rfsoftmax serve-bench --threads 8 --sampler.shards 8  # serving load test
//! rfsoftmax serve-bench --transport uds --mix 8:1:1     # cross-process wire
//! rfsoftmax serve-bench --transport tcp --wave 32       # TCP + batched waves
//! rfsoftmax stats tcp:127.0.0.1:7411                    # scrape live telemetry
//! rfsoftmax snapshot tcp:127.0.0.1:7411 --out snaps     # durable state capture
//! rfsoftmax serve-bench --restore snaps:main            # warm restart from it
//! rfsoftmax bench-check BENCH_serving.json              # validate BENCH JSON
//! ```

use anyhow::{bail, Result};
use rfsoftmax::cli::{render_help, Args, FlagSpec};
use rfsoftmax::config::Config;
use rfsoftmax::coordinator::TrainerBuilder;
use rfsoftmax::json::to_string_pretty;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::runtime::Runtime;


fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "info" => cmd_info(rest),
        "sample" => cmd_sample(rest),
        "bias" => cmd_bias(rest),
        "serve-bench" => cmd_serve_bench(rest),
        "stats" => cmd_stats(rest),
        "snapshot" => cmd_snapshot(rest),
        "bench-check" => cmd_bench_check(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!(
            "unknown command '{other}' (try: train, info, sample, bias, \
             serve-bench, stats, snapshot, bench-check)"
        ),
    }
}

fn print_usage() {
    println!(
        "rfsoftmax — Sampled Softmax with Random Fourier Features (NeurIPS 2019)\n\n\
         Commands:\n  \
         train        train a model with a configured negative sampler\n  \
         info         list compiled AOT artifacts\n  \
         sample       standalone sampling demo (no artifacts needed)\n  \
         bias         gradient-bias diagnostic (Theorem 1 empirics)\n  \
         serve-bench  closed-loop load test of the serving subsystem\n  \
         stats        scrape live telemetry from a serving endpoint\n  \
         snapshot     fetch a serving endpoint's durable sampler snapshot\n  \
         bench-check  validate BENCH JSON records (CI bench-smoke gate)\n\n\
         Run `rfsoftmax <command> --help` for flags."
    );
}

/// Split raw args into (known command flags, config overrides): anything
/// with a '.' in the key is treated as a config override.
fn split_config_overrides(a: &Args) -> Vec<(String, String)> {
    a.overrides()
        .filter(|(k, _)| k.contains('.'))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

fn cmd_train(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help", "stale-sampling"])?;
    if a.has("help") {
        println!(
            "{}",
            render_help(
                "train",
                "train a model on the fused native backend (default) or \
                 the pjrt artifacts",
                &[
                    FlagSpec {
                        name: "prefix",
                        help: "run/artifact prefix (quickstart|ptb|bnews|xc_*)",
                        default: Some("quickstart".into()),
                    },
                    FlagSpec {
                        name: "config",
                        help: "JSON config file",
                        default: None,
                    },
                    FlagSpec {
                        name: "artifacts",
                        help: "artifact directory (train.backend = pjrt only)",
                        default: Some("artifacts".into()),
                    },
                    FlagSpec {
                        name: "stale-sampling",
                        help: "sample with the previous step's query (pipelined mode)",
                        default: None,
                    },
                    FlagSpec {
                        name: "<section>.<key>",
                        help: "any config override, e.g. --sampler.kind rff",
                        default: None,
                    },
                ]
            )
        );
        return Ok(());
    }
    let prefix = a.str_or("prefix", "quickstart").to_string();
    let dir = a.str_or("artifacts", "artifacts").to_string();
    // Shape sources, least to most specific: the corpus-prefix preset
    // (the native backend's kernel shapes), then the JSON config file,
    // then explicit CLI overrides. Later sources win.
    let mut cfg = Config::default();
    rfsoftmax::coordinator::harness::prefix_preset(&mut cfg, &prefix)?;
    if let Some(p) = a.get("config") {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("cannot read {p}: {e}"))?;
        let j = rfsoftmax::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
        cfg.apply_json(&j)?;
    }
    for (k, v) in split_config_overrides(&a) {
        cfg.set(&k, &v)?;
    }
    cfg.validate()?;
    let runtime = Runtime::for_train(&cfg, &dir)?;
    println!(
        "platform: {} | prefix: {prefix} | sampler: {}",
        runtime.platform(),
        cfg.sampler.kind.name()
    );
    let mut trainer = TrainerBuilder::new(&runtime, &prefix, cfg)
        .stale_sampling(a.has("stale-sampling"))
        .build()?;
    let report = trainer.run()?;
    println!(
        "done: sampler={} steps={} final_metric={:.4} wall={:.1}s",
        report.sampler, report.steps_run, report.final_metric, report.wall_seconds
    );
    println!("curve: {}", report.curve());
    println!("{}", to_string_pretty(&report.to_json()));
    Ok(())
}

fn cmd_info(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help"])?;
    let dir = a.str_or("artifacts", "artifacts").to_string();
    // The default backend needs no artifacts; report it first, then list
    // any pjrt artifact directory that happens to be loadable.
    let native = Runtime::native();
    println!("default backend: {}", native.platform());
    match Runtime::load(&dir) {
        Ok(runtime) => {
            println!("pjrt artifacts in {dir}:");
            for meta in runtime.manifest().iter() {
                let ins: Vec<String> = meta
                    .inputs
                    .iter()
                    .map(|t| format!("{}:{}{:?}", t.name, t.dtype, t.shape))
                    .collect();
                println!(
                    "  {:<28} {} -> {} outputs",
                    meta.name,
                    ins.join(" "),
                    meta.outputs.len()
                );
            }
        }
        Err(e) => println!("pjrt backend unavailable: {e:#}"),
    }
    Ok(())
}

fn cmd_sample(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help"])?;
    let cfg = Config::load(a.get("config"), split_config_overrides(&a).into_iter())?;
    let n = cfg.model.num_classes.min(10_000);
    let d = cfg.model.embed_dim.min(128);
    let mut rng = Rng::seeded(cfg.sampler.seed);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let sampler = rfsoftmax::coordinator::build_sampler(
        &cfg,
        &classes,
        Some(&vec![1.0; n]),
        &mut rng,
    )?;
    let h = unit_vector(&mut rng, d);
    let t0 = std::time::Instant::now();
    let draw = sampler.sample(&h, cfg.sampler.num_negatives, &mut rng);
    let dt = t0.elapsed();
    println!(
        "sampler={} n={n} d={d} m={} wall={:?}",
        sampler.name(),
        draw.len(),
        dt
    );
    for (id, q) in draw.ids.iter().zip(&draw.probs).take(10) {
        println!("  class {id:>6}  q = {q:.3e}");
    }
    Ok(())
}

/// Closed-loop serving load generator: R reader threads issuing a
/// configurable mix of `sample`/`probability`/`top_k` requests — either
/// straight into the micro-batcher (`--transport inproc`) or as real
/// wire-protocol clients over a unix socket (`--transport uds`) — while
/// a writer applies batched class updates and publishes epoch-tagged
/// snapshot swaps. Emits a human-readable summary plus a
/// machine-readable `BENCH {json}` line (qps, p50/p99 latency,
/// coalescing, swap stalls, frame codec overhead).
fn cmd_serve_bench(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help", "no-writer", "hedge"])?;
    if a.has("help") {
        println!(
            "{}",
            render_help(
                "serve-bench",
                "closed-loop load test of the serving subsystem (no artifacts needed)",
                &[
                    FlagSpec {
                        name: "threads",
                        help: "concurrent reader threads (uds: one connection each)",
                        default: Some("4".into()),
                    },
                    FlagSpec {
                        name: "requests",
                        help: "requests per reader",
                        default: Some("2000".into()),
                    },
                    FlagSpec {
                        name: "transport",
                        help: "inproc (direct batcher calls), uds \
                               (unix-socket wire), or tcp (cross-machine \
                               wire; binds serving.listen)",
                        default: Some("inproc".into()),
                    },
                    FlagSpec {
                        name: "wave",
                        help: "pack each reader's pipelined burst into \
                               wire v3 wave frames of N sub-requests \
                               (1 = one frame per request; uds/tcp only)",
                        default: Some("1".into()),
                    },
                    FlagSpec {
                        name: "replicas",
                        help: "spin N in-process serving replicas — each \
                               owning one consistent-hash shard of the \
                               class universe — and route the load \
                               through the L5 cluster router (uds/tcp \
                               only; adds cluster lag/failover/hedge \
                               cells to the BENCH record)",
                        default: Some("1".into()),
                    },
                    FlagSpec {
                        name: "hedge",
                        help: "hedge straggling replica sub-requests \
                               after a p99-derived delay (cluster path \
                               only)",
                        default: None,
                    },
                    FlagSpec {
                        name: "mix",
                        help: "sample:prob:topk request-mix weights",
                        default: Some("1:0:0".into()),
                    },
                    FlagSpec {
                        name: "top-k",
                        help: "k for top_k requests in the mix",
                        default: Some("10".into()),
                    },
                    FlagSpec {
                        name: "churn",
                        help: "class-universe churn adds:retires[:ops] \
                               (admin frames over uds; reports mutation \
                               latency + post-churn qps)",
                        default: None,
                    },
                    FlagSpec {
                        name: "updates-per-swap",
                        help: "classes updated per writer publish cycle",
                        default: Some("32".into()),
                    },
                    FlagSpec {
                        name: "no-writer",
                        help: "serve a static snapshot (no update churn)",
                        default: None,
                    },
                    FlagSpec {
                        name: "hold",
                        help: "keep the transport listening N seconds \
                               after the load completes, so an external \
                               `rfsoftmax stats` can scrape the live \
                               telemetry (uds/tcp only)",
                        default: Some("0".into()),
                    },
                    FlagSpec {
                        name: "restore",
                        help: "warm-start from a durable snapshot saved \
                               by `rfsoftmax snapshot`: DIR or DIR:NAME \
                               (name defaults to 'main'); the config \
                               must rebuild the same feature map the \
                               snapshot was captured under \
                               (fingerprint-checked; single-node only)",
                        default: None,
                    },
                    FlagSpec {
                        name: "config",
                        help: "JSON config file",
                        default: None,
                    },
                    FlagSpec {
                        name: "<section>.<key>",
                        help: "any config override, e.g. --sampler.shards 8",
                        default: None,
                    },
                ]
            )
        );
        return Ok(());
    }
    let cfg = Config::load(a.get("config"), split_config_overrides(&a).into_iter())?;
    let threads = a.usize_or("threads", 4)?;
    let requests = a.usize_or("requests", 2000)?;
    let transport =
        rfsoftmax::serving::TransportMode::parse(a.str_or("transport", "inproc"))?;
    let wave = a.usize_or("wave", 1)?;
    let mix = rfsoftmax::serving::RequestMix::parse(a.str_or("mix", "1:0:0"))?;
    let top_k = a.usize_or("top-k", 10)?;
    let churn = match a.get("churn") {
        Some(s) => Some(rfsoftmax::serving::ChurnSpec::parse(s)?),
        None => None,
    };
    let updates_per_swap = if a.has("no-writer") {
        0
    } else {
        a.usize_or("updates-per-swap", 32)?
    };
    let hold = a.usize_or("hold", 0)?;
    let replicas = a.usize_or("replicas", 1)?;
    let hedge = a.has("hedge");
    // `DIR` or `DIR:NAME` — rsplit so a directory path containing ':'
    // still parses when the name is given explicitly.
    let restore = match a.get("restore") {
        Some(spec) => {
            let (dir, name) = match spec.rsplit_once(':') {
                Some((d, n)) if !d.is_empty() && !n.is_empty() => (d, n),
                _ => (spec, "main"),
            };
            let snap = rfsoftmax::snapshot::load_with_manifest(
                std::path::Path::new(dir),
                name,
            )
            .map_err(|e| anyhow::anyhow!("--restore {spec}: {e}"))?;
            println!(
                "restore: {dir}:{name} kind={} epoch={} ({}/{} classes live)",
                snap.state.kind_name(),
                snap.epoch,
                snap.state.live_classes(),
                snap.state.num_classes(),
            );
            Some(std::sync::Arc::new(snap))
        }
        None => None,
    };
    let n = cfg.model.num_classes.min(50_000);
    let d = cfg.model.embed_dim.min(128);
    let mut rng = Rng::seeded(cfg.sampler.seed);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let spec = rfsoftmax::serving::LoadSpec {
        readers: threads,
        requests_per_reader: requests,
        m: cfg.sampler.num_negatives,
        top_k,
        dim: d,
        seed: cfg.sampler.seed,
        batcher: rfsoftmax::serving::BatcherOptions {
            max_batch: cfg.serving.max_batch,
            max_wait: std::time::Duration::from_micros(cfg.serving.max_wait_us),
        },
        updates_per_swap,
        swap_pause: std::time::Duration::from_micros(200),
        transport,
        mix,
        churn,
        wave,
        listen: cfg.serving.listen.clone(),
        quantize: cfg.sampler.quantize,
        hold: std::time::Duration::from_secs(hold as u64),
        replicas,
        hedge,
        virtual_nodes: cfg.cluster.virtual_nodes,
        restore,
    };
    let report = if replicas > 1 {
        // Cluster path: the class universe is pre-partitioned by the
        // consistent-hash ring, one sampler per replica over exactly
        // its shard, and the load runs through the L5 router.
        let parts = rfsoftmax::cluster::shard_partition(
            n,
            replicas,
            cfg.cluster.virtual_nodes,
        );
        let mut samplers = Vec::with_capacity(replicas);
        for p in &parts {
            let mut shard = Matrix::zeros(p.len(), d);
            for (i, &g) in p.iter().enumerate() {
                shard.row_mut(i).copy_from_slice(classes.row(g as usize));
            }
            samplers.push(rfsoftmax::coordinator::build_sampler(
                &cfg,
                &shard,
                Some(&vec![1.0; p.len()]),
                &mut rng,
            )?);
        }
        println!(
            "serve-bench: sampler={} n={n} d={d} m={} transport={} \
             replicas={replicas} hedge={hedge} wave={wave} mix={} \
             readers={threads} requests/reader={requests} max_batch={} \
             max_wait={}µs",
            samplers[0].name(),
            spec.m,
            transport.name(),
            mix.label(),
            cfg.serving.max_batch,
            cfg.serving.max_wait_us,
        );
        rfsoftmax::serving::run_cluster_closed_loop(&samplers, &spec)?
    } else {
        let sampler = rfsoftmax::coordinator::build_sampler(
            &cfg,
            &classes,
            Some(&vec![1.0; n]),
            &mut rng,
        )?;
        println!(
            "serve-bench: sampler={} n={n} d={d} m={} transport={} \
             wave={wave} mix={} readers={threads} requests/reader={requests} \
             max_batch={} max_wait={}µs",
            sampler.name(),
            spec.m,
            transport.name(),
            mix.label(),
            cfg.serving.max_batch,
            cfg.serving.max_wait_us,
        );
        rfsoftmax::serving::run_closed_loop(sampler.as_ref(), &spec)?
    };
    println!("{}", report.render());
    println!("BENCH {}", report.to_json());
    Ok(())
}

/// Resolve the `stats` endpoint syntax and connect: `tcp:HOST:PORT`,
/// `uds:PATH`, or a bare value (a '/' means a socket path, anything
/// else a TCP address).
fn connect_stats_endpoint(
    spec: &str,
) -> Result<rfsoftmax::transport::TransportClient> {
    use rfsoftmax::transport::TransportClient;
    let client = if let Some(addr) = spec.strip_prefix("tcp:") {
        TransportClient::connect_tcp(addr)
    } else if let Some(path) = spec.strip_prefix("uds:") {
        TransportClient::connect(path)
    } else if spec.contains('/') {
        TransportClient::connect(spec)
    } else {
        TransportClient::connect_tcp(spec)
    };
    client.map_err(|e| anyhow::anyhow!("connect {spec}: {e}"))
}

/// Scrape the live telemetry of a running serving transport: connect,
/// send the read-only wire-v3 `STATS` admin frame, and print the JSON
/// the server returns (batcher counters, snapshot epoch, per-stage
/// latency histograms, slow-request log, transport frame counters).
/// `--expect-stage-count N` turns the scrape into a machine
/// reconciliation check — each per-request stage histogram
/// (queue_wait / coalesce / gemm_wave / tree_walk) must have recorded
/// exactly N requests — which is how CI proves a live server's
/// telemetry agrees with the load it just served.
fn cmd_stats(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help", "json"])?;
    if a.has("help") {
        println!(
            "{}",
            render_help(
                "stats",
                "scrape live telemetry (STATS frame) from a serving endpoint",
                &[
                    FlagSpec {
                        name: "json",
                        help: "print the raw JSON exactly as returned \
                               (default pretty-prints)",
                        default: None,
                    },
                    FlagSpec {
                        name: "expect-stage-count",
                        help: "fail unless each per-request stage \
                               histogram count equals N (reconciliation \
                               check for CI)",
                        default: None,
                    },
                    FlagSpec {
                        name: "<endpoints…>",
                        help: "tcp:HOST:PORT | uds:PATH | bare \
                               address/path (positional; several \
                               endpoints scrape a whole replica \
                               cluster and print a merged snapshot \
                               with per-replica epoch / epoch-lag \
                               columns)",
                        default: None,
                    },
                ]
            )
        );
        return Ok(());
    }
    a.check_known(&["help", "json", "expect-stage-count"])?;
    let endpoints = a.positional();
    anyhow::ensure!(
        !endpoints.is_empty(),
        "stats: give at least one endpoint (tcp:HOST:PORT | uds:PATH)"
    );
    if endpoints.len() > 1 {
        return stats_cluster(endpoints, a.has("json"), a.get("expect-stage-count"));
    }
    let endpoint = &endpoints[0];
    let mut client = connect_stats_endpoint(endpoint)?;
    let text = client
        .stats()
        .map_err(|e| anyhow::anyhow!("STATS scrape failed: {e}"))?;
    let j = rfsoftmax::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("STATS returned invalid JSON: {e}"))?;
    if let Some(raw_n) = a.get("expect-stage-count") {
        let n: i64 = raw_n.parse().map_err(|_| {
            anyhow::anyhow!("--expect-stage-count: bad count '{raw_n}'")
        })?;
        for stage in ["queue_wait", "coalesce", "gemm_wave", "tree_walk"] {
            let got = j
                .at(&["telemetry", "stages", stage, "count"])
                .and_then(|v| v.as_i64());
            anyhow::ensure!(
                got == Some(n),
                "stats: stage '{stage}' count {got:?} does not reconcile \
                 with the expected {n} requests"
            );
        }
        println!("stats: stage counts reconcile at {n} requests");
    }
    if a.has("json") {
        println!("{text}");
    } else {
        println!("{}", to_string_pretty(&j));
    }
    Ok(())
}

/// Multi-endpoint `stats`: scrape every replica of a serving cluster
/// and print one merged snapshot. Per-replica columns include the
/// snapshot epoch and `epoch_lag` — how far each replica's epoch
/// trails the most-advanced one, the scrape-side view of replication
/// lag (every replicated churn apply publishes exactly one epoch, so
/// a converged cluster shows lag 0 everywhere). The router-side lag
/// (queued log entries) lives in the cluster's own telemetry; this
/// command needs nothing but the replicas' `STATS` frames, so it works
/// against any wire-v3 servers.
fn stats_cluster(
    endpoints: &[String],
    raw_json: bool,
    expect_stage_count: Option<&str>,
) -> Result<()> {
    anyhow::ensure!(
        expect_stage_count.is_none(),
        "stats: --expect-stage-count reconciles a single endpoint \
         against one load's request total — scrape replicas one at a \
         time for that"
    );
    let mut snaps: Vec<(String, rfsoftmax::json::Json)> = Vec::new();
    for ep in endpoints {
        let mut client = connect_stats_endpoint(ep)?;
        let text = client
            .stats()
            .map_err(|e| anyhow::anyhow!("STATS scrape of {ep} failed: {e}"))?;
        let j = rfsoftmax::json::parse(&text).map_err(|e| {
            anyhow::anyhow!("{ep}: STATS returned invalid JSON: {e}")
        })?;
        snaps.push((ep.clone(), j));
    }
    let epoch_of = |j: &rfsoftmax::json::Json| -> i64 {
        j.at(&["server", "epoch"]).and_then(|v| v.as_i64()).unwrap_or(0)
    };
    let count_of = |j: &rfsoftmax::json::Json, path: &[&str]| -> i64 {
        j.at(path).and_then(|v| v.as_i64()).unwrap_or(0)
    };
    let max_epoch = snaps.iter().map(|(_, j)| epoch_of(j)).max().unwrap_or(0);
    let mut replicas = Vec::with_capacity(snaps.len());
    let (mut total_requests, mut total_frames) = (0i64, 0i64);
    for (ep, j) in &snaps {
        let epoch = epoch_of(j);
        let requests = count_of(j, &["batcher", "requests"]);
        let frames = count_of(j, &["transport", "request_frames"]);
        total_requests += requests;
        total_frames += frames;
        replicas.push(rfsoftmax::json::Json::obj(vec![
            ("endpoint", rfsoftmax::json::Json::from(ep.as_str())),
            ("epoch", rfsoftmax::json::Json::from(epoch as f64)),
            (
                "epoch_lag",
                rfsoftmax::json::Json::from((max_epoch - epoch) as f64),
            ),
            ("requests", rfsoftmax::json::Json::from(requests as f64)),
            ("stats", j.clone()),
        ]));
    }
    let merged = rfsoftmax::json::Json::obj(vec![
        ("replicas", rfsoftmax::json::Json::Arr(replicas)),
        (
            "merged",
            rfsoftmax::json::Json::obj(vec![
                ("count", rfsoftmax::json::Json::from(snaps.len())),
                ("max_epoch", rfsoftmax::json::Json::from(max_epoch as f64)),
                (
                    "total_requests",
                    rfsoftmax::json::Json::from(total_requests as f64),
                ),
                (
                    "total_request_frames",
                    rfsoftmax::json::Json::from(total_frames as f64),
                ),
            ]),
        ),
    ]);
    if raw_json {
        println!("{merged}");
        return Ok(());
    }
    println!(
        "{:<28} {:>8} {:>10} {:>10}",
        "endpoint", "epoch", "epoch_lag", "requests"
    );
    for (ep, j) in &snaps {
        let epoch = epoch_of(j);
        println!(
            "{:<28} {:>8} {:>10} {:>10}",
            ep,
            epoch,
            max_epoch - epoch,
            count_of(j, &["batcher", "requests"]),
        );
    }
    println!(
        "merged: replicas={} max_epoch={max_epoch} total_requests=\
         {total_requests} total_request_frames={total_frames}",
        snaps.len()
    );
    Ok(())
}

/// Capture a running server's durable sampler state: send the wire-v3
/// `STATE_SNAPSHOT` request, reassemble the chunk stream, decode it
/// with the codec's typed checks (magic / version / checksum), and
/// save it under a manifest-tracked name. This is the capture half of
/// the warm-restart cycle — `serve-bench --restore DIR:NAME` is the
/// restore half, and a cluster operator feeds the same artifact to a
/// recovered replica before `Cluster::bootstrap_replica` replays the
/// log tail.
fn cmd_snapshot(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help"])?;
    if a.has("help") {
        println!(
            "{}",
            render_help(
                "snapshot",
                "fetch a serving endpoint's durable sampler snapshot \
                 and save it under a manifest-tracked name",
                &[
                    FlagSpec {
                        name: "out",
                        help: "snapshot directory (manifest.json + *.rfsnap)",
                        default: Some("snapshots".into()),
                    },
                    FlagSpec {
                        name: "name",
                        help: "manifest entry name (re-saving a name \
                               replaces its artifact)",
                        default: Some("main".into()),
                    },
                    FlagSpec {
                        name: "max-chunk",
                        help: "cap response chunks at this many bytes \
                               (0 = server default; a testing aid for \
                               the chunked stream)",
                        default: Some("0".into()),
                    },
                    FlagSpec {
                        name: "<endpoint>",
                        help: "tcp:HOST:PORT | uds:PATH (positional)",
                        default: None,
                    },
                ]
            )
        );
        return Ok(());
    }
    a.check_known(&["help", "out", "name", "max-chunk"])?;
    let [endpoint] = a.positional() else {
        bail!(
            "snapshot: give exactly one serving endpoint \
             (tcp:HOST:PORT or uds:PATH)"
        );
    };
    let out = std::path::PathBuf::from(a.str_or("out", "snapshots"));
    let name = a.str_or("name", "main");
    let max_chunk = a.usize_or("max-chunk", 0)? as u32;
    let mut client = connect_stats_endpoint(endpoint)?;
    let t0 = std::time::Instant::now();
    let (bytes, epoch) = client
        .fetch_snapshot(max_chunk)
        .map_err(|e| anyhow::anyhow!("snapshot fetch from {endpoint}: {e}"))?;
    let fetched = t0.elapsed();
    // Full typed decode before anything touches disk: a server bug (or
    // a torn stream) surfaces here as BadChecksum/Malformed, not as a
    // poisoned artifact discovered at restore time.
    let snap = rfsoftmax::snapshot::decode(&bytes)
        .map_err(|e| anyhow::anyhow!("snapshot from {endpoint}: {e}"))?;
    anyhow::ensure!(
        snap.epoch == epoch,
        "snapshot from {endpoint}: chunk headers claim epoch {epoch} but \
         the decoded state carries epoch {}",
        snap.epoch
    );
    let meta = rfsoftmax::snapshot::save_with_manifest(&out, name, &snap)
        .map_err(|e| anyhow::anyhow!("save under {}: {e}", out.display()))?;
    println!(
        "snapshot: {endpoint} -> {} ({} bytes in {fetched:.1?})",
        out.join(&meta.file).display(),
        bytes.len(),
    );
    println!(
        "  name={} kind={} epoch={} classes={}/{} checksum={:#018x}",
        meta.name,
        meta.kind,
        meta.epoch,
        meta.live_classes,
        meta.n_classes,
        meta.checksum,
    );
    Ok(())
}

/// Validate BENCH JSON artifacts with the in-crate `json` parser — the
/// CI `bench-smoke` gate. Each positional file may hold raw
/// `BENCH {json}` lines (as the benches print them) or bare JSON lines;
/// every record must parse, and at least one record must exist overall.
/// With `--require-wave-amortization R`, the serving records must also
/// prove the batched-wave win: some tcp `wave > 1` record's
/// `req_headers_per_request` must be ≤ 1/R of a tcp `wave == 1` record's
/// at the same mix (the ISSUE 5 acceptance gate, checked by machine
/// rather than by review). With `--require-simd-speedup R`, some
/// `simd_matmul_nt` record must show the vectorized microkernel ≥ R×
/// the scalar reference (the ISSUE 6 gate). With
/// `--require-fused-speedup R`, some `train_step_fused` record must
/// show the fused one-pass native train step ≥ R× the composed
/// stage-by-stage baseline (the ISSUE 9 gate). With
/// `--require-restore-speedup R`, some `warm_restart` record must show
/// the snapshot state swap ≥ R× the cold rebuild-and-replay recovery
/// path (the ISSUE 10 durability gate). With
/// `--require-telemetry-overhead P`, every serving record's attributed
/// telemetry cost (`telemetry_overhead_pct`) must be ≤ P percent — the
/// observability budget, checked by machine. With `--baseline FILE`,
/// every record whose (bench, identity-fields) cell also appears in
/// FILE must keep its throughput metric within `--max-regression` %
/// of the baseline value — the cross-run perf ratchet.
/// Parse one file of `BENCH {json}` (or bare JSON) lines into `out`;
/// returns how many records the file contributed. Every record must
/// parse and carry a `bench` tag.
fn read_bench_records(
    file: &str,
    out: &mut Vec<rfsoftmax::json::Json>,
) -> Result<usize> {
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow::anyhow!("read {file}: {e}"))?;
    let mut in_file = 0usize;
    for (lineno, line) in text.lines().enumerate() {
        let body = match line.strip_prefix("BENCH ") {
            Some(b) => b,
            None if line.trim_start().starts_with('{') => line,
            None => continue,
        };
        let j = rfsoftmax::json::parse(body).map_err(|e| {
            anyhow::anyhow!("{file}:{}: invalid BENCH JSON: {e}", lineno + 1)
        })?;
        anyhow::ensure!(
            j.get("bench").and_then(|b| b.as_str()).is_some(),
            "{file}:{}: BENCH record lacks a 'bench' tag",
            lineno + 1
        );
        in_file += 1;
        out.push(j);
    }
    Ok(in_file)
}

/// Identity fields + higher-is-better throughput metric per bench tag.
/// Two records agreeing on the tag and every identity field are "the
/// same cell" across runs; the metric is what `--baseline` ratchets.
/// Tags not listed here are validated but never baseline-compared.
fn bench_identity(tag: &str) -> Option<(&'static [&'static str], &'static str)> {
    match tag {
        "serving_closed_loop" => Some((
            &[
                "sampler", "transport", "mix", "readers", "wave", "churn",
                "quantize", "simd", "replicas",
            ],
            "qps",
        )),
        "batch_vs_scalar_sampling" => {
            Some((&["n", "batch", "m", "smoke"], "batch_samples_per_sec"))
        }
        "simd_matmul_nt" => {
            Some((&["r", "k", "d", "simd", "smoke"], "simd_per_sec"))
        }
        "quantized_sampler" => Some((
            &["n", "d", "m", "quantize", "simd", "smoke"],
            "draws_per_sec",
        )),
        "train_step_fused" => Some((
            &["task", "b", "l", "d", "h", "m", "simd", "smoke"],
            "fused_steps_per_sec",
        )),
        "warm_restart" => {
            Some((&["n", "d", "shards", "smoke"], "restore_per_sec"))
        }
        _ => None,
    }
}

/// `(cell key, metric value)` for one BENCH record, when its tag has a
/// registered identity. Missing identity fields key as `-` so older
/// baseline records (fewer fields) never alias a different cell.
fn bench_cell(j: &rfsoftmax::json::Json) -> Option<(String, f64)> {
    let tag = j.get("bench")?.as_str()?;
    let (fields, metric) = bench_identity(tag)?;
    let value = j.get(metric)?.as_f64()?;
    let mut key = String::from(tag);
    for f in fields {
        key.push('|');
        match j.get(f) {
            Some(v) => key.push_str(&v.to_string()),
            None => key.push('-'),
        }
    }
    Some((key, value))
}

fn cmd_bench_check(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help"])?;
    if a.has("help") {
        println!(
            "{}",
            render_help(
                "bench-check",
                "validate BENCH JSON records emitted by the benches",
                &[
                    FlagSpec {
                        name: "require-wave-amortization",
                        help: "also require a tcp wave>1 serving record \
                               with per-request header overhead reduced \
                               by ≥ this factor vs the wave=1 record at \
                               the same mix",
                        default: None,
                    },
                    FlagSpec {
                        name: "require-simd-speedup",
                        help: "also require a simd_matmul_nt record with \
                               the vectorized microkernel ≥ this factor \
                               over the scalar reference",
                        default: None,
                    },
                    FlagSpec {
                        name: "require-fused-speedup",
                        help: "also require a train_step_fused record \
                               with the fused one-pass train step ≥ this \
                               factor over the composed stage-by-stage \
                               baseline",
                        default: None,
                    },
                    FlagSpec {
                        name: "require-restore-speedup",
                        help: "also require a warm_restart record with \
                               the snapshot state swap ≥ this factor \
                               over the cold rebuild + churn-replay \
                               recovery path",
                        default: None,
                    },
                    FlagSpec {
                        name: "require-telemetry-overhead",
                        help: "also require every serving record's \
                               attributed telemetry cost \
                               (telemetry_overhead_pct) ≤ this percent",
                        default: None,
                    },
                    FlagSpec {
                        name: "require-replica-speedup",
                        help: "also require a replicas>1 serving record \
                               with qps ≥ this factor over the \
                               single-replica record at the same \
                               transport/mix/wave/readers/churn, with \
                               no abandoned replication entries and a \
                               bounded steady-state replication lag",
                        default: None,
                    },
                    FlagSpec {
                        name: "baseline",
                        help: "BENCH file from a previous run; matching \
                               cells must not regress their throughput \
                               metric by more than --max-regression %",
                        default: None,
                    },
                    FlagSpec {
                        name: "max-regression",
                        help: "allowed per-cell throughput drop vs \
                               --baseline, in percent",
                        default: Some("50".into()),
                    },
                    FlagSpec {
                        name: "<files…>",
                        help: "files of BENCH lines (positional)",
                        default: None,
                    },
                ]
            )
        );
        return Ok(());
    }
    a.check_known(&[
        "help",
        "require-wave-amortization",
        "require-simd-speedup",
        "require-fused-speedup",
        "require-restore-speedup",
        "require-telemetry-overhead",
        "require-replica-speedup",
        "baseline",
        "max-regression",
    ])?;
    anyhow::ensure!(
        !a.positional().is_empty(),
        "bench-check: give at least one BENCH file"
    );
    let mut records: Vec<rfsoftmax::json::Json> = Vec::new();
    for file in a.positional() {
        let in_file = read_bench_records(file, &mut records)?;
        anyhow::ensure!(in_file > 0, "{file}: no BENCH records found");
        println!("bench-check: {file}: {in_file} records ok");
    }
    if let Some(factor) = a.get("require-wave-amortization") {
        let factor: f64 = factor.parse().map_err(|_| {
            anyhow::anyhow!("--require-wave-amortization: bad factor '{factor}'")
        })?;
        let serving = |j: &rfsoftmax::json::Json, key: &str| -> Option<f64> {
            if j.get("bench")?.as_str()? != "serving_closed_loop"
                || j.get("transport")?.as_str()? != "tcp"
            {
                return None;
            }
            j.get(key)?.as_f64()
        };
        // Best (baseline, waved) pair = the one with the largest
        // reduction, over all same-mix tcp record pairs.
        let mut best: Option<(f64, f64)> = None;
        for base in &records {
            let (Some(1), Some(hdr)) = (
                base.get("wave").and_then(|w| w.as_usize()),
                serving(base, "req_headers_per_request"),
            ) else {
                continue;
            };
            let mix = base.get("mix").and_then(|m| m.as_str());
            for waved in &records {
                let (Some(w), Some(whdr)) = (
                    waved.get("wave").and_then(|w| w.as_usize()),
                    serving(waved, "req_headers_per_request"),
                ) else {
                    continue;
                };
                if w <= 1 || waved.get("mix").and_then(|m| m.as_str()) != mix {
                    continue;
                }
                let reduction = hdr / whdr.max(1e-12);
                let best_reduction =
                    best.map_or(0.0, |(b, v)| b / v.max(1e-12));
                if reduction > best_reduction {
                    best = Some((hdr, whdr));
                }
            }
        }
        let Some((baseline, waved)) = best else {
            bail!(
                "bench-check: no tcp wave=1/wave>1 serving record pair at a \
                 shared mix — cannot prove wave amortization"
            );
        };
        let reduction = baseline / waved.max(1e-12);
        anyhow::ensure!(
            reduction >= factor,
            "bench-check: header overhead reduced {reduction:.1}× \
             (baseline {baseline:.4} → waved {waved:.4}), need ≥ {factor}×"
        );
        println!(
            "bench-check: wave amortization {reduction:.1}× \
             (hdr/req {baseline:.4} → {waved:.4}) ≥ {factor}× ok"
        );
    }
    if let Some(factor) = a.get("require-simd-speedup") {
        let factor: f64 = factor.parse().map_err(|_| {
            anyhow::anyhow!("--require-simd-speedup: bad factor '{factor}'")
        })?;
        // Best speedup over all simd_matmul_nt cells: the gate proves
        // the dispatcher beats the scalar reference somewhere, and a
        // forced-scalar record (speedup ≈ 1) cannot mask a real one.
        let best = records
            .iter()
            .filter(|j| {
                j.get("bench").and_then(|b| b.as_str())
                    == Some("simd_matmul_nt")
            })
            .filter_map(|j| j.get("speedup").and_then(|s| s.as_f64()))
            .fold(f64::NEG_INFINITY, f64::max);
        anyhow::ensure!(
            best.is_finite(),
            "bench-check: no simd_matmul_nt record with a 'speedup' field \
             — cannot prove the SIMD win"
        );
        anyhow::ensure!(
            best >= factor,
            "bench-check: simd matmul_nt speedup {best:.2}× over scalar, \
             need ≥ {factor}×"
        );
        println!("bench-check: simd speedup {best:.2}× ≥ {factor}× ok");
    }
    if let Some(factor) = a.get("require-fused-speedup") {
        let factor: f64 = factor.parse().map_err(|_| {
            anyhow::anyhow!("--require-fused-speedup: bad factor '{factor}'")
        })?;
        // Best fused-vs-composed speedup over all train_step_fused
        // cells: the gate proves the one-pass kernel path beats the
        // stage-by-stage composed baseline somewhere (same math, same
        // gemm microkernels — the delta is fusion + scratch reuse).
        let best = records
            .iter()
            .filter(|j| {
                j.get("bench").and_then(|b| b.as_str())
                    == Some("train_step_fused")
            })
            .filter_map(|j| j.get("speedup").and_then(|s| s.as_f64()))
            .fold(f64::NEG_INFINITY, f64::max);
        anyhow::ensure!(
            best.is_finite(),
            "bench-check: no train_step_fused record with a 'speedup' \
             field — cannot prove the fused-step win"
        );
        anyhow::ensure!(
            best >= factor,
            "bench-check: fused train step {best:.2}× over the composed \
             baseline, need ≥ {factor}×"
        );
        println!("bench-check: fused-step speedup {best:.2}× ≥ {factor}× ok");
    }
    if let Some(factor) = a.get("require-restore-speedup") {
        let factor: f64 = factor.parse().map_err(|_| {
            anyhow::anyhow!("--require-restore-speedup: bad factor '{factor}'")
        })?;
        // Best warm-vs-cold recovery speedup: restoring a captured
        // snapshot into a skeleton (the serving `apply_restore` path)
        // against rebuilding from seed embeddings and replaying the
        // whole add/retire churn history. The one-time codec decode is
        // reported separately as `decode_ms` by the bench.
        let best = records
            .iter()
            .filter(|j| {
                j.get("bench").and_then(|b| b.as_str()) == Some("warm_restart")
            })
            .filter_map(|j| j.get("restore_speedup").and_then(|s| s.as_f64()))
            .fold(f64::NEG_INFINITY, f64::max);
        anyhow::ensure!(
            best.is_finite(),
            "bench-check: no warm_restart record with a 'restore_speedup' \
             field — cannot prove the warm-restart win"
        );
        anyhow::ensure!(
            best >= factor,
            "bench-check: snapshot restore {best:.2}× over cold rebuild + \
             replay, need ≥ {factor}×"
        );
        println!("bench-check: restore speedup {best:.2}× ≥ {factor}× ok");
    }
    if let Some(limit) = a.get("require-telemetry-overhead") {
        let limit: f64 = limit.parse().map_err(|_| {
            anyhow::anyhow!(
                "--require-telemetry-overhead: bad percent '{limit}'"
            )
        })?;
        // Every serving record must carry the attributed overhead and
        // stay under budget — one over-budget cell fails the gate, so a
        // cheap cell can never mask an expensive one.
        let mut worst = f64::NEG_INFINITY;
        let mut seen = 0usize;
        for j in &records {
            if j.get("bench").and_then(|b| b.as_str())
                != Some("serving_closed_loop")
            {
                continue;
            }
            let pct = j
                .get("telemetry_overhead_pct")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "bench-check: serving record lacks \
                         telemetry_overhead_pct — cannot prove the \
                         telemetry budget"
                    )
                })?;
            seen += 1;
            worst = worst.max(pct);
            anyhow::ensure!(
                pct <= limit,
                "bench-check: attributed telemetry overhead {pct:.3}% \
                 exceeds the {limit}% budget"
            );
        }
        anyhow::ensure!(
            seen > 0,
            "bench-check: no serving_closed_loop record — cannot prove \
             the telemetry budget"
        );
        println!(
            "bench-check: telemetry overhead worst {worst:.3}% ≤ {limit}% \
             ok ({seen} serving records)"
        );
    }
    if let Some(factor) = a.get("require-replica-speedup") {
        let factor: f64 = factor.parse().map_err(|_| {
            anyhow::anyhow!("--require-replica-speedup: bad factor '{factor}'")
        })?;
        // "Bounded lag": the worst per-replica replication backlog a
        // qualifying cluster record may report at steady state (the
        // load generator samples it when the readers finish, before
        // the convergence flush).
        const MAX_REPLICA_LAG: usize = 8;
        // A record pair is comparable when everything but the replica
        // count matches — same transport, mix, wave, reader count, and
        // churn schedule — so the speedup measures the cluster, not a
        // config delta. Records without a 'replicas' field (older
        // baselines) count as single-replica.
        let shape = |j: &rfsoftmax::json::Json| -> Option<(String, usize, f64)> {
            if j.get("bench")?.as_str()? != "serving_closed_loop" {
                return None;
            }
            let key = format!(
                "{}|{}|{}|{}|{}",
                j.get("transport")?.as_str()?,
                j.get("mix")?.as_str()?,
                j.get("wave")?.as_usize()?,
                j.get("readers")?.as_usize()?,
                j.get("churn").and_then(|c| c.as_str()).unwrap_or("-"),
            );
            let replicas =
                j.get("replicas").and_then(|r| r.as_usize()).unwrap_or(1);
            Some((key, replicas, j.get("qps")?.as_f64()?))
        };
        let mut best: Option<(f64, f64, usize)> = None; // (single, multi, n)
        for single in &records {
            let Some((key, 1, qps1)) = shape(single) else { continue };
            for multi in &records {
                let Some((mkey, n, qpsn)) = shape(multi) else { continue };
                if n <= 1 || mkey != key {
                    continue;
                }
                // Lost replication entries mean the cluster shed churn
                // to go fast, and an unbounded steady-state replication
                // backlog means it deferred the work instead of doing
                // it — neither record can prove the win.
                let dropped = multi
                    .get("repl_dropped")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0);
                let lag = multi
                    .get("repl_lag")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0);
                if dropped > 0 || lag > MAX_REPLICA_LAG {
                    continue;
                }
                let speedup = qpsn / qps1.max(1e-12);
                let best_speedup =
                    best.map_or(0.0, |(s, m, _)| m / s.max(1e-12));
                if speedup > best_speedup {
                    best = Some((qps1, qpsn, n));
                }
            }
        }
        let Some((qps1, qpsn, n)) = best else {
            bail!(
                "bench-check: no comparable replicas=1 / replicas>1 \
                 serving record pair (same transport/mix/wave/readers/\
                 churn, repl_dropped=0, repl_lag ≤ {MAX_REPLICA_LAG}) — \
                 cannot prove the replica speedup"
            );
        };
        let speedup = qpsn / qps1.max(1e-12);
        anyhow::ensure!(
            speedup >= factor,
            "bench-check: {n}-replica qps {qpsn:.0} is {speedup:.2}× the \
             single-replica {qps1:.0}, need ≥ {factor}×"
        );
        println!(
            "bench-check: replica speedup {speedup:.2}× \
             ({qps1:.0} → {qpsn:.0} qps at {n} replicas) ≥ {factor}× ok"
        );
    }
    if let Some(baseline_file) = a.get("baseline") {
        let max_regression: f64 =
            a.str_or("max-regression", "50").parse().map_err(|_| {
                anyhow::anyhow!(
                    "--max-regression: bad percentage '{}'",
                    a.str_or("max-regression", "50")
                )
            })?;
        anyhow::ensure!(
            (0.0..100.0).contains(&max_regression),
            "--max-regression must be in [0, 100), got {max_regression}"
        );
        let mut base_records = Vec::new();
        read_bench_records(baseline_file, &mut base_records)?;
        // Duplicate cells (reruns in one file) keep the best value on
        // both sides: the ratchet compares best-vs-best, not noise.
        let mut base: std::collections::HashMap<String, f64> =
            std::collections::HashMap::new();
        for j in &base_records {
            if let Some((key, v)) = bench_cell(j) {
                let e = base.entry(key).or_insert(v);
                *e = e.max(v);
            }
        }
        let mut current: std::collections::HashMap<String, f64> =
            std::collections::HashMap::new();
        for j in &records {
            if let Some((key, v)) = bench_cell(j) {
                let e = current.entry(key).or_insert(v);
                *e = e.max(v);
            }
        }
        let mut compared = 0usize;
        let mut failures = Vec::new();
        for (key, now) in &current {
            let Some(&was) = base.get(key) else { continue };
            if !(was > 0.0 && now.is_finite()) {
                continue;
            }
            compared += 1;
            let floor = was * (1.0 - max_regression / 100.0);
            if *now < floor {
                failures.push(format!(
                    "{key}: {now:.0} < {floor:.0} \
                     (baseline {was:.0}, -{max_regression}% allowed)"
                ));
            }
        }
        if !failures.is_empty() {
            failures.sort();
            bail!(
                "bench-check: {} cell(s) regressed past --max-regression \
                 {max_regression}%:\n  {}",
                failures.len(),
                failures.join("\n  ")
            );
        }
        println!(
            "bench-check: {compared} baseline cell(s) within \
             {max_regression}% of {baseline_file}"
        );
    }
    println!("bench-check: {} records valid", records.len());
    Ok(())
}

fn cmd_bias(raw: &[String]) -> Result<()> {
    let a = Args::parse(raw, &["help"])?;
    let cfg = Config::load(a.get("config"), split_config_overrides(&a).into_iter())?;
    let n = cfg.model.num_classes.min(200);
    let d = cfg.model.embed_dim.min(32);
    let trials = a.usize_or("trials", 3000)?;
    let mut rng = Rng::seeded(cfg.sampler.seed);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let sampler = rfsoftmax::coordinator::build_sampler(
        &cfg,
        &classes,
        Some(&vec![1.0; n]),
        &mut rng,
    )?;
    let h = unit_vector(&mut rng, d);
    let est = rfsoftmax::bias::empirical_bias(
        &classes,
        &h,
        0,
        cfg.model.tau,
        sampler.as_ref(),
        cfg.sampler.num_negatives,
        trials,
        &mut rng,
    );
    let diag = rfsoftmax::bias::theorem_diagnostics(
        &classes,
        &h,
        0,
        cfg.model.tau,
        sampler.as_ref(),
        cfg.sampler.num_negatives,
    );
    println!(
        "sampler={} n={n} m={} trials={trials}",
        sampler.name(),
        cfg.sampler.num_negatives
    );
    println!("  |bias|_inf = {:.4e} (MC se {:.1e})", est.linf, est.max_se);
    println!("  |bias|_2   = {:.4e}", est.l2);
    println!("  UB1        = {:.4e}", diag.ub1);
    println!(
        "  Σe²ᵒ/q vs floor: {:.4e} / {:.4e}",
        diag.sum_sq_over_q, diag.floor
    );
    Ok(())
}
