//! Metrics substrate: counters, timers, EWMAs, streaming statistics and
//! histograms, plus JSON/CSV emitters. The coordinator records per-phase
//! timings (sample / execute / optimize / tree-update) through this module;
//! the bench harness reuses [`Summary`] for reporting.

use crate::json::Json;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

pub mod live;

/// Geometric midpoint of log bucket `i` (covering `[2^i, 2^{i+1})`
/// nanoseconds): `√2 · 2^i`. Quantile estimates quote this instead of
/// the upper bucket edge, which would overstate by up to 2×. Saturates
/// at the top bucket.
pub(crate) fn bucket_midpoint_ns(i: usize) -> u64 {
    if i >= 63 {
        return u64::MAX;
    }
    ((1u64 << i) as f64 * std::f64::consts::SQRT_2) as u64
}

/// Streaming mean/variance/min/max via Welford's algorithm.
#[derive(Clone, Debug, Default)]
pub struct Stream {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stream {
    pub fn record(&mut self, x: f64) {
        self.n += 1;
        if self.n == 1 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exponentially-weighted moving average (for smoothed loss curves).
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, value: None }
    }

    pub fn record(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

/// Fixed-bucket latency histogram (log-spaced, nanoseconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^{i+1}) ns; 64 buckets cover everything.
    buckets: [u64; 64],
    stream: Stream,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: [0; 64], stream: Stream::default() }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, d: Duration) {
        let ns = d.as_nanos().max(1) as u64;
        let idx = 63 - ns.leading_zeros() as usize;
        self.buckets[idx] += 1;
        self.stream.record(ns as f64);
    }

    pub fn count(&self) -> u64 {
        self.stream.count()
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.stream.mean() as u64)
    }

    /// Approximate quantile from the log buckets: the geometric
    /// midpoint of the bucket holding the q-th sample (see
    /// [`bucket_midpoint_ns`]), so the estimate is centered within its
    /// bucket rather than overstated at the upper power-of-two edge.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.stream.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_nanos(bucket_midpoint_ns(i));
            }
        }
        Duration::from_nanos(u64::MAX)
    }

    /// Largest recorded duration (exact, from the Welford stream — not
    /// bucket-quantized).
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.stream.max() as u64)
    }
}

/// A registry of named metrics for one run.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    streams: BTreeMap<String, Stream>,
    timers: BTreeMap<String, LatencyHistogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn observe(&mut self, name: &str, x: f64) {
        self.streams.entry(name.to_string()).or_default().record(x);
    }

    pub fn stream(&self, name: &str) -> Option<&Stream> {
        self.streams.get(name)
    }

    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record_duration(name, t0.elapsed());
        out
    }

    pub fn record_duration(&mut self, name: &str, d: Duration) {
        self.timers.entry(name.to_string()).or_default().record(d);
    }

    pub fn timer(&self, name: &str) -> Option<&LatencyHistogram> {
        self.timers.get(name)
    }

    /// Dump everything as JSON (for EXPERIMENTS.md records).
    pub fn to_json(&self) -> Json {
        let mut counters = Vec::new();
        for (k, v) in &self.counters {
            counters.push((k.as_str(), Json::from(*v as usize)));
        }
        let mut streams = Vec::new();
        for (k, s) in &self.streams {
            streams.push((
                k.as_str(),
                Json::obj(vec![
                    ("count", Json::from(s.count() as usize)),
                    ("mean", Json::from(s.mean())),
                    ("stddev", Json::from(s.stddev())),
                    ("min", Json::from(s.min())),
                    ("max", Json::from(s.max())),
                ]),
            ));
        }
        let mut timers = Vec::new();
        for (k, t) in &self.timers {
            timers.push((
                k.as_str(),
                Json::obj(vec![
                    ("count", Json::from(t.count() as usize)),
                    ("mean_us", Json::from(t.mean().as_secs_f64() * 1e6)),
                    (
                        "p50_us",
                        Json::from(t.quantile(0.5).as_secs_f64() * 1e6),
                    ),
                    (
                        "p95_us",
                        Json::from(t.quantile(0.95).as_secs_f64() * 1e6),
                    ),
                    (
                        "p99_us",
                        Json::from(t.quantile(0.99).as_secs_f64() * 1e6),
                    ),
                    ("max_us", Json::from(t.max().as_secs_f64() * 1e6)),
                ]),
            ));
        }
        Json::obj(vec![
            ("counters", Json::obj(counters)),
            ("streams", Json::obj(streams)),
            ("timers", Json::obj(timers)),
        ])
    }
}

/// Simple scoped timer returning elapsed seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_stats() {
        let mut s = Stream::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.record(10.0), 10.0);
        let v = e.record(0.0);
        assert!((v - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for us in [1u64, 10, 100, 1000, 10_000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(1.0));
        // Geometric midpoint, not the upper power-of-two edge: a lone
        // 100µs sample must be estimated *inside* its bucket
        // [2^16, 2^17) ns, where the old upper-edge answer (2^17 ns ≈
        // 131µs) overstated it.
        let mut one = LatencyHistogram::default();
        one.record(Duration::from_micros(100));
        let est = one.quantile(0.5).as_nanos() as u64;
        assert!((1u64 << 16) <= est && est < (1u64 << 17), "est {est}");
        assert_eq!(one.max(), Duration::from_micros(100));
    }

    #[test]
    fn registry_counters_and_json() {
        let mut m = Metrics::new();
        m.incr("steps", 3);
        m.observe("loss", 1.5);
        m.observe("loss", 0.5);
        m.time("op", || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(m.counter("steps"), 3);
        assert!((m.stream("loss").unwrap().mean() - 1.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.at(&["counters", "steps"]).unwrap().as_i64(), Some(3));
        assert!(j.at(&["timers", "op", "mean_us"]).unwrap().as_f64().unwrap() >= 1000.0);
        assert!(j.at(&["timers", "op", "p99_us"]).unwrap().as_f64().is_some());
        assert!(j.at(&["timers", "op", "max_us"]).unwrap().as_f64().unwrap() >= 1000.0);
    }
}
