//! Live, lock-free telemetry for the serving stack.
//!
//! The [`Metrics`](super::Metrics) registry in the parent module is
//! single-owner: every `record` takes `&mut self`, which is exactly
//! right for the trainer loop and exactly wrong for a serving stack
//! where dozens of reader/writer/transport threads record
//! concurrently. This module is the concurrent counterpart, built from
//! two std-only primitives:
//!
//! * [`LiveHistogram`] — the 64-bucket log-spaced latency histogram
//!   from [`super::LatencyHistogram`], but with `AtomicU64` cells.
//!   Hot-path recording is a single `Relaxed` `fetch_add` per bucket
//!   (plus count/sum/max upkeep), never a mutex; readers take a
//!   [`HistogramSnapshot`] and merge/quantile off-thread. Quantiles
//!   quote the geometric bucket midpoint, matching the fixed
//!   upper-edge bias of the single-threaded histogram.
//! * [`ShardedCounter`] — a cache-line-padded array of `AtomicU64`
//!   shards with a thread-sticky shard index, so unrelated threads
//!   bumping the same logical counter do not ping-pong one cache line.
//!
//! [`LiveRegistry`] composes them into the one handle the serving
//! layers share (cloned into batcher / transport / writer workers —
//! clones are `Arc`-shallow): six fixed per-request **stage**
//! histograms ([`Stage`]: decode → queue wait → coalesce → gemm wave →
//! tree walk → encode/reply), named counters and histograms registered
//! on a cold mutex path but recorded lock-free, and a bounded worst-N
//! [`SlowLog`] whose hot path is one `Relaxed` threshold load for
//! every request that is *not* among the worst.
//!
//! Recording is gated per registry by [`LiveRegistry::set_enabled`]:
//! disabled, a stage record costs one relaxed load and a branch — the
//! "telemetry off" comparator the serve-bench overhead cell measures
//! against (budget: ≤ 2% of request cost, machine-checked in CI).

use crate::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-request serving stages, in pipeline order. `Decode` and
/// `EncodeReply` only occur on wire transports (uds/tcp); the middle
/// four are recorded for every request on every transport, so their
/// snapshot counts reconcile exactly with the request totals a load
/// generator observes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Wire-frame payload parse (CPU only — socket wait excluded).
    Decode,
    /// Submit → drain latency in the coalescing queue.
    QueueWait,
    /// Batch admission: dim-grouping plus activation-matrix build.
    Coalesce,
    /// The fused feature-map gemm over the coalesced wave.
    GemmWave,
    /// Per-row tree sampling/scoring after the gemm.
    TreeWalk,
    /// Response-frame encode (wire transports).
    EncodeReply,
}

/// Number of [`Stage`] variants (the registry's histogram array size).
pub const STAGE_COUNT: usize = 6;

impl Stage {
    /// Every stage, in pipeline order.
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::Decode,
        Stage::QueueWait,
        Stage::Coalesce,
        Stage::GemmWave,
        Stage::TreeWalk,
        Stage::EncodeReply,
    ];

    /// Stable snake_case name (JSON key in STATS snapshots).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Decode => "decode",
            Stage::QueueWait => "queue_wait",
            Stage::Coalesce => "coalesce",
            Stage::GemmWave => "gemm_wave",
            Stage::TreeWalk => "tree_walk",
            Stage::EncodeReply => "encode_reply",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

/// Lock-free log-bucket latency histogram: bucket `i` covers
/// `[2^i, 2^{i+1})` ns, recording is one relaxed `fetch_add` per cell.
/// Readers call [`LiveHistogram::snapshot`]; a snapshot taken while
/// writers are mid-record is still well-formed (each cell is atomic),
/// merely a momentary view.
pub struct LiveHistogram {
    buckets: [AtomicU64; 64],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LiveHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LiveHistogram {
    pub fn new() -> Self {
        LiveHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration. All updates are `Relaxed`: per-cell totals
    /// are exact once writers quiesce; cross-cell consistency is not
    /// needed for bucket counting.
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// [`LiveHistogram::record`] with a raw nanosecond value.
    pub fn record_ns(&self, ns: u64) {
        let idx = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Records so far (relaxed read).
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Materialize the current cells into a plain snapshot.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].load(Ordering::Relaxed)
            }),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }
}

/// A plain (non-atomic) copy of a [`LiveHistogram`]: mergeable across
/// shards/replicas and quantile-queryable without touching the hot
/// cells again.
#[derive(Clone, Debug, Default)]
pub struct HistogramSnapshot {
    buckets: [u64; 64],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Fold another snapshot into this one (bucket-wise sum) — how
    /// per-thread or per-replica histograms aggregate.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// Approximate quantile: geometric midpoint of the bucket holding
    /// the q-th sample, same estimator as
    /// [`super::LatencyHistogram::quantile`].
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return super::bucket_midpoint_ns(i);
            }
        }
        u64::MAX
    }

    /// `{count, mean_us, p50_us, p99_us, max_us}` — the shape every
    /// STATS consumer (CLI, BENCH records, bench-check) parses.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::from(self.count as usize)),
            ("mean_us", Json::from(self.mean_ns() / 1e3)),
            ("p50_us", Json::from(self.quantile_ns(0.5) as f64 / 1e3)),
            ("p99_us", Json::from(self.quantile_ns(0.99) as f64 / 1e3)),
            ("max_us", Json::from(self.max_ns as f64 / 1e3)),
        ])
    }
}

/// Shards in a [`ShardedCounter`]. More than typical recorder-thread
/// counts collide on; small enough that summing on the read path stays
/// trivial.
const COUNTER_SHARDS: usize = 16;

/// One cache line per shard so two threads bumping the same logical
/// counter never write the same line.
#[repr(align(64))]
struct PaddedCell(AtomicU64);

/// Monotonic counter sharded across cache-line-padded cells; each
/// thread sticks to one shard (assigned round-robin on first use), so
/// the hot path is an uncontended relaxed `fetch_add`.
pub struct ShardedCounter {
    shards: [PaddedCell; COUNTER_SHARDS],
}

static SHARD_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SHARD_IDX: std::cell::Cell<usize> =
        std::cell::Cell::new(usize::MAX);
}

fn my_shard() -> usize {
    SHARD_IDX.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = SHARD_SEQ.fetch_add(1, Ordering::Relaxed) % COUNTER_SHARDS;
        c.set(v);
        v
    })
}

impl Default for ShardedCounter {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedCounter {
    pub fn new() -> Self {
        ShardedCounter {
            shards: std::array::from_fn(|_| PaddedCell(AtomicU64::new(0))),
        }
    }

    pub fn add(&self, n: u64) {
        self.shards[my_shard()].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn incr(&self) {
        self.add(1);
    }

    /// Sum across shards. Exact once writers quiesce; a momentary
    /// under-count is possible mid-`add`, never an over-count.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// One entry of the worst-N slow-request log: the request's total
/// latency plus its per-stage breakdown (nanoseconds, indexed like
/// [`Stage::ALL`]; stages that did not occur hold zero).
#[derive(Clone, Debug)]
pub struct SlowRequest {
    /// Submit → reply, nanoseconds.
    pub total_ns: u64,
    /// Request kind ("sample" / "probability" / "top_k").
    pub kind: &'static str,
    /// How many requests shared the coalesced batch this one rode in.
    pub batch: usize,
    /// Snapshot epoch the request was served under.
    pub epoch: u64,
    /// Per-stage nanoseconds, `stage_ns[Stage::ALL[i]]`.
    pub stage_ns: [u64; STAGE_COUNT],
}

impl SlowRequest {
    fn to_json(&self) -> Json {
        let mut stages = BTreeMap::new();
        for s in Stage::ALL {
            let ns = self.stage_ns[s.index()];
            if ns > 0 {
                stages.insert(s.name().to_string(), Json::from(ns as f64 / 1e3));
            }
        }
        Json::obj(vec![
            ("total_us", Json::from(self.total_ns as f64 / 1e3)),
            ("kind", Json::from(self.kind)),
            ("batch", Json::from(self.batch)),
            ("epoch", Json::from(self.epoch as usize)),
            ("stages_us", Json::Obj(stages)),
        ])
    }
}

/// Capacity of the slow-request log.
const SLOW_LOG_CAP: usize = 8;

/// Bounded worst-N log. The hot path for a request that is *not*
/// among the current worst is one relaxed load of the admission
/// threshold — the mutex is taken only when a request actually
/// displaces an entry, which by construction happens at most
/// `SLOW_LOG_CAP + O(log of the latency ceiling)` times per regime.
struct SlowLog {
    /// Admission bar: the smallest total in a full log (0 until full).
    threshold_ns: AtomicU64,
    entries: Mutex<Vec<SlowRequest>>,
}

impl SlowLog {
    fn new() -> Self {
        SlowLog {
            threshold_ns: AtomicU64::new(0),
            entries: Mutex::new(Vec::with_capacity(SLOW_LOG_CAP)),
        }
    }

    fn offer(&self, r: SlowRequest) {
        if r.total_ns <= self.threshold_ns.load(Ordering::Relaxed) {
            return;
        }
        let mut entries = self.entries.lock().unwrap();
        if entries.len() == SLOW_LOG_CAP {
            // Evict the current fastest (checked again under the lock:
            // the threshold may have moved since the relaxed load).
            let (mi, min_total) = entries
                .iter()
                .enumerate()
                .map(|(i, e)| (i, e.total_ns))
                .min_by_key(|&(_, t)| t)
                .expect("slow log is non-empty at capacity");
            if r.total_ns <= min_total {
                return;
            }
            entries.swap_remove(mi);
        }
        entries.push(r);
        if entries.len() == SLOW_LOG_CAP {
            let min_total = entries.iter().map(|e| e.total_ns).min().unwrap_or(0);
            self.threshold_ns.store(min_total, Ordering::Relaxed);
        }
    }

    /// Worst-first copy of the log.
    fn snapshot(&self) -> Vec<SlowRequest> {
        let mut v = self.entries.lock().unwrap().clone();
        v.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
        v
    }
}

struct RegistryInner {
    enabled: AtomicBool,
    stages: [LiveHistogram; STAGE_COUNT],
    counters: Mutex<BTreeMap<String, Arc<ShardedCounter>>>,
    histograms: Mutex<BTreeMap<String, Arc<LiveHistogram>>>,
    slow: SlowLog,
}

/// The shared telemetry handle of one serving stack. Cloning is
/// `Arc`-shallow — the batcher creates one registry and every
/// transport/writer worker records into the same cells. One registry
/// per serving stack (not process-global), so concurrently running
/// stacks — or tests — never cross-contaminate.
#[derive(Clone)]
pub struct LiveRegistry {
    inner: Arc<RegistryInner>,
}

impl Default for LiveRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl LiveRegistry {
    pub fn new() -> Self {
        LiveRegistry {
            inner: Arc::new(RegistryInner {
                enabled: AtomicBool::new(true),
                stages: std::array::from_fn(|_| LiveHistogram::new()),
                counters: Mutex::new(BTreeMap::new()),
                histograms: Mutex::new(BTreeMap::new()),
                slow: SlowLog::new(),
            }),
        }
    }

    /// Toggle recording. Disabled, every record degrades to one
    /// relaxed load + branch — the "telemetry off" side of the
    /// overhead budget.
    pub fn set_enabled(&self, on: bool) {
        self.inner.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.inner.enabled.load(Ordering::Relaxed)
    }

    /// Record one per-request stage duration (nanoseconds). For
    /// batch-shared stages the caller records each request's *share*
    /// (`batch duration / batch size`), keeping per-stage counts equal
    /// to request counts and sums equal to attributed CPU time.
    pub fn record_stage_ns(&self, stage: Stage, ns: u64) {
        if self.enabled() {
            self.inner.stages[stage.index()].record_ns(ns);
        }
    }

    /// [`LiveRegistry::record_stage_ns`] with a `Duration`.
    pub fn record_stage(&self, stage: Stage, d: Duration) {
        self.record_stage_ns(stage, d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Current snapshot of one stage histogram.
    pub fn stage_snapshot(&self, stage: Stage) -> HistogramSnapshot {
        self.inner.stages[stage.index()].snapshot()
    }

    /// Get-or-register a named counter (cold path takes a mutex; keep
    /// the returned handle and bump it lock-free thereafter).
    pub fn counter(&self, name: &str) -> Arc<ShardedCounter> {
        let mut map = self.inner.counters.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(ShardedCounter::new())),
        )
    }

    /// Get-or-register a named histogram (same contract as
    /// [`LiveRegistry::counter`]).
    pub fn histogram(&self, name: &str) -> Arc<LiveHistogram> {
        let mut map = self.inner.histograms.lock().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(LiveHistogram::new())),
        )
    }

    /// Offer a completed request to the worst-N slow log.
    pub fn offer_slow(&self, r: SlowRequest) {
        if self.enabled() {
            self.inner.slow.offer(r);
        }
    }

    /// Worst-first copy of the slow-request log.
    pub fn slow_requests(&self) -> Vec<SlowRequest> {
        self.inner.slow.snapshot()
    }

    /// Per-stage `{name: {count, mean_us, p50_us, p99_us, max_us}}`
    /// for every stage that has recorded at least once.
    pub fn stages_json(&self) -> Json {
        let mut stages = BTreeMap::new();
        for s in Stage::ALL {
            let snap = self.stage_snapshot(s);
            if snap.count() > 0 {
                stages.insert(s.name().to_string(), snap.to_json());
            }
        }
        Json::Obj(stages)
    }

    /// Full registry snapshot: stages, named counters/histograms, and
    /// the slow-request log. The core of the STATS wire answer.
    pub fn snapshot_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), Json::from(c.get() as usize)))
            .collect();
        let histograms: BTreeMap<String, Json> = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, h)| h.count() > 0)
            .map(|(k, h)| (k.clone(), h.snapshot().to_json()))
            .collect();
        let slowest: Vec<Json> = self.slow_requests().iter().map(|r| r.to_json()).collect();
        Json::obj(vec![
            ("enabled", Json::from(self.enabled())),
            ("stages", self.stages_json()),
            ("counters", Json::Obj(counters)),
            ("histograms", Json::Obj(histograms)),
            ("slowest", Json::Arr(slowest)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::LatencyHistogram;

    /// Deterministic per-thread duration sequence (no RNG needed).
    fn synth_ns(thread: u64, i: u64) -> u64 {
        (thread * 7919 + i * 263) % 2_000_000 + 1
    }

    #[test]
    fn concurrent_recording_matches_single_threaded_reference() {
        const THREADS: u64 = 8;
        const PER_THREAD: u64 = 2_000;
        let live = Arc::new(LiveHistogram::new());
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let live = Arc::clone(&live);
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        live.record_ns(synth_ns(t, i));
                    }
                });
            }
        });
        let snap = live.snapshot();
        assert_eq!(snap.count(), THREADS * PER_THREAD);

        // Single-threaded reference over the identical sample set: the
        // merged concurrent snapshot must agree on every quantile (both
        // use the same buckets and the same midpoint estimator).
        let mut reference = LatencyHistogram::default();
        for t in 0..THREADS {
            for i in 0..PER_THREAD {
                reference.record(Duration::from_nanos(synth_ns(t, i)));
            }
        }
        for q in [0.5, 0.9, 0.99, 1.0] {
            let live_q = snap.quantile_ns(q);
            let ref_q = reference.quantile(q).as_nanos() as u64;
            assert_eq!(live_q, ref_q, "quantile {q}: live {live_q} vs ref {ref_q}");
        }
    }

    #[test]
    fn snapshot_merge_sums_counts() {
        let a = LiveHistogram::new();
        let b = LiveHistogram::new();
        for i in 1..100u64 {
            a.record_ns(i * 1000);
            b.record_ns(i * 37);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.count(), 198);
        assert_eq!(merged.max_ns(), 99_000);
        assert!(merged.quantile_ns(1.0) >= merged.quantile_ns(0.5));
    }

    #[test]
    fn sharded_counter_is_exact_across_threads() {
        let c = Arc::new(ShardedCounter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.incr();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn slow_log_keeps_the_worst_n() {
        let reg = LiveRegistry::new();
        for total in 1..=100u64 {
            reg.offer_slow(SlowRequest {
                total_ns: total * 1000,
                kind: "sample",
                batch: 1,
                epoch: 0,
                stage_ns: [0; STAGE_COUNT],
            });
        }
        let worst = reg.slow_requests();
        assert_eq!(worst.len(), SLOW_LOG_CAP);
        // Worst-first, and exactly the top-N totals survived.
        assert_eq!(worst[0].total_ns, 100_000);
        assert_eq!(worst[SLOW_LOG_CAP - 1].total_ns, 93_000);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let reg = LiveRegistry::new();
        reg.set_enabled(false);
        reg.record_stage_ns(Stage::GemmWave, 1234);
        reg.offer_slow(SlowRequest {
            total_ns: u64::MAX,
            kind: "sample",
            batch: 1,
            epoch: 0,
            stage_ns: [0; STAGE_COUNT],
        });
        assert_eq!(reg.stage_snapshot(Stage::GemmWave).count(), 0);
        assert!(reg.slow_requests().is_empty());
        reg.set_enabled(true);
        reg.record_stage_ns(Stage::GemmWave, 1234);
        assert_eq!(reg.stage_snapshot(Stage::GemmWave).count(), 1);
    }

    #[test]
    fn registry_snapshot_json_shape() {
        let reg = LiveRegistry::new();
        reg.counter("requests").add(7);
        reg.histogram("publish_wait").record_ns(1_000_000);
        reg.record_stage_ns(Stage::TreeWalk, 5_000);
        let j = reg.snapshot_json();
        assert_eq!(j.at(&["counters", "requests"]).unwrap().as_i64(), Some(7));
        assert_eq!(j.at(&["histograms", "publish_wait", "count"]).unwrap().as_i64(), Some(1));
        assert_eq!(j.at(&["stages", "tree_walk", "count"]).unwrap().as_i64(), Some(1));
        // Round-trips through the in-crate parser (the STATS scrape
        // path re-parses exactly this).
        let text = j.to_string();
        let back = crate::json::parse(&text).expect("snapshot reparses");
        assert_eq!(back.at(&["counters", "requests"]).unwrap().as_i64(), Some(7));
    }
}
