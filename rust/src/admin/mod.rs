//! Unified admin surface: one typed vocabulary for every place the
//! served class universe can be mutated, captured, or restored.
//!
//! Before this module the crate had three parallel admin dialects:
//!
//! - [`crate::serving::DoubleBufferedSampler::extend_vocab`] /
//!   `retire_classes` returned `Result<_, String>`,
//! - the coordinator's `SamplerService` mirrored the same two methods
//!   with its own signatures, and
//! - the transport layer's `VocabAdmin` hook spoke `(dim, rows, data)`
//!   triples with stringly errors.
//!
//! Each grew independently, so snapshot/restore would have been a
//! *fourth* dialect. Instead, every surface now implements
//! [`AdminSurface`] — a single entry point taking a typed [`AdminOp`]
//! and returning a typed [`AdminResponse`] or [`AdminError`]. Vocab
//! churn and durability ops ([`AdminOp::Snapshot`] /
//! [`AdminOp::Restore`]) are peers: anything that can grow the universe
//! can also checkpoint it.
//!
//! The old method names survive for one release as thin `#[deprecated]`
//! shims delegating to [`AdminSurface::admin`]; new code should go
//! through the trait (or the typed convenience wrappers
//! [`AdminSurface::admin_add`] et al.).
//!
//! # Visibility semantics
//!
//! The `epoch` carried by a response is the snapshot epoch the surface
//! observed when the op was accepted. Immediate surfaces (the
//! transport server's writer, which publishes per-op) return the epoch
//! at which the mutation is already visible; staged surfaces
//! ([`crate::serving::DoubleBufferedSampler`], which batches churn into
//! the next `sync`) return the *currently published* epoch — the op
//! lands at the next step boundary. Both are documented on the
//! respective impls.

use crate::linalg::Matrix;
use crate::sampler::VocabError;
use crate::snapshot::{SamplerState, Snapshot, SnapshotError};
use std::fmt;

/// One administrative operation against a served sampler. The class
/// universe mutations mirror [`crate::sampler::Sampler::add_classes`] /
/// `retire_classes`; the durability ops mirror
/// [`crate::sampler::Sampler::snapshot_state`] / `restore_state` but
/// run through the surface's staging discipline (readers never observe
/// partial state).
#[derive(Clone, Debug)]
pub enum AdminOp {
    /// Grow the universe: each row of `embeddings` becomes a new class;
    /// the response carries the assigned contiguous ids.
    AddClasses { embeddings: Matrix },
    /// Retire live classes into permanent holes. Ids must be live and
    /// duplicate-free.
    RetireClasses { ids: Vec<u32> },
    /// Capture the full durable sampler state at the published epoch.
    Snapshot,
    /// Replace the full sampler state from a previously captured (or
    /// decoded) snapshot. Boxed: a state is `O(n·D)` and `AdminOp`
    /// travels through channels by value.
    Restore { state: Box<SamplerState> },
}

impl AdminOp {
    /// Stable lowercase tag, for metrics and log lines.
    pub fn name(&self) -> &'static str {
        match self {
            AdminOp::AddClasses { .. } => "add_classes",
            AdminOp::RetireClasses { .. } => "retire_classes",
            AdminOp::Snapshot => "snapshot",
            AdminOp::Restore { .. } => "restore",
        }
    }
}

/// Successful outcome of an [`AdminOp`], variant-matched to the op.
#[derive(Clone, Debug)]
pub enum AdminResponse {
    /// `AddClasses` accepted: the ids assigned to the new rows, and the
    /// epoch observed at acceptance (see module docs for visibility).
    Added { ids: Vec<u32>, epoch: u64 },
    /// `RetireClasses` accepted.
    Retired { epoch: u64 },
    /// `Snapshot` captured. Boxed for the same reason as
    /// [`AdminOp::Restore`].
    Snapshot { snapshot: Box<Snapshot> },
    /// `Restore` accepted and staged/applied.
    Restored { epoch: u64 },
}

impl AdminResponse {
    fn kind(&self) -> &'static str {
        match self {
            AdminResponse::Added { .. } => "added",
            AdminResponse::Retired { .. } => "retired",
            AdminResponse::Snapshot { .. } => "snapshot",
            AdminResponse::Restored { .. } => "restored",
        }
    }
}

/// Single error type for every admin surface, absorbing the layer-local
/// errors the three pre-unification dialects used to leak.
#[derive(Clone, Debug, PartialEq)]
pub enum AdminError {
    /// The sampler rejected a universe mutation (fixed-universe kind,
    /// retired/duplicate/out-of-range ids).
    Vocab(VocabError),
    /// Snapshot capture/restore failed (corrupt bytes, wrong feature
    /// map, kind mismatch — see [`SnapshotError`]).
    Snapshot(SnapshotError),
    /// A remote peer answered with a wire `Error` frame; `code` is the
    /// transport error code.
    Remote { code: u8, message: String },
    /// The op could not reach (or round-trip to) the surface: socket
    /// errors, dead writer threads, mismatched response variants.
    Transport(String),
    /// The surface cannot perform this op at all (e.g. restore over the
    /// wire); the payload names the surface.
    Unsupported(&'static str),
}

impl fmt::Display for AdminError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdminError::Vocab(e) => write!(f, "admin: {e}"),
            AdminError::Snapshot(e) => write!(f, "admin: {e}"),
            AdminError::Remote { code, message } => {
                write!(f, "admin: remote error {code}: {message}")
            }
            AdminError::Transport(msg) => write!(f, "admin: transport: {msg}"),
            AdminError::Unsupported(surface) => {
                write!(f, "admin: op not supported by surface '{surface}'")
            }
        }
    }
}

impl std::error::Error for AdminError {}

impl From<VocabError> for AdminError {
    fn from(e: VocabError) -> Self {
        AdminError::Vocab(e)
    }
}

impl From<SnapshotError> for AdminError {
    fn from(e: SnapshotError) -> Self {
        AdminError::Snapshot(e)
    }
}

/// Anything that can administer a served sampler: the trainer-side
/// double buffer, the coordinator service, the transport server's
/// writer hook, and the transport *client* (which forwards ops over the
/// wire) all implement this one trait, so tooling — the CLI, the
/// cluster bootstrap path, tests — is written once against
/// `&mut dyn AdminSurface`.
pub trait AdminSurface {
    /// Execute one admin op. Implementations must be atomic per op:
    /// on `Err` the served state is unchanged.
    fn admin(&mut self, op: AdminOp) -> Result<AdminResponse, AdminError>;

    /// Typed wrapper for [`AdminOp::AddClasses`].
    fn admin_add(
        &mut self,
        embeddings: Matrix,
    ) -> Result<(Vec<u32>, u64), AdminError> {
        match self.admin(AdminOp::AddClasses { embeddings })? {
            AdminResponse::Added { ids, epoch } => Ok((ids, epoch)),
            other => Err(unexpected("added", &other)),
        }
    }

    /// Typed wrapper for [`AdminOp::RetireClasses`].
    fn admin_retire(&mut self, ids: Vec<u32>) -> Result<u64, AdminError> {
        match self.admin(AdminOp::RetireClasses { ids })? {
            AdminResponse::Retired { epoch } => Ok(epoch),
            other => Err(unexpected("retired", &other)),
        }
    }

    /// Typed wrapper for [`AdminOp::Snapshot`].
    fn admin_snapshot(&mut self) -> Result<Snapshot, AdminError> {
        match self.admin(AdminOp::Snapshot)? {
            AdminResponse::Snapshot { snapshot } => Ok(*snapshot),
            other => Err(unexpected("snapshot", &other)),
        }
    }

    /// Typed wrapper for [`AdminOp::Restore`].
    fn admin_restore(
        &mut self,
        state: SamplerState,
    ) -> Result<u64, AdminError> {
        match self.admin(AdminOp::Restore { state: Box::new(state) })? {
            AdminResponse::Restored { epoch } => Ok(epoch),
            other => Err(unexpected("restored", &other)),
        }
    }
}

fn unexpected(wanted: &'static str, got: &AdminResponse) -> AdminError {
    AdminError::Transport(format!(
        "surface answered '{}' to an op expecting '{wanted}'",
        got.kind()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy surface that answers the *wrong* variant, to pin down the
    /// wrapper's mismatch handling.
    struct Contrary;
    impl AdminSurface for Contrary {
        fn admin(&mut self, op: AdminOp) -> Result<AdminResponse, AdminError> {
            match op {
                AdminOp::Snapshot => Ok(AdminResponse::Retired { epoch: 7 }),
                _ => Err(AdminError::Unsupported("contrary")),
            }
        }
    }

    #[test]
    fn wrappers_reject_mismatched_response_variants() {
        let err = Contrary.admin_snapshot().unwrap_err();
        match err {
            AdminError::Transport(msg) => {
                assert!(msg.contains("retired"), "{msg}");
                assert!(msg.contains("snapshot"), "{msg}");
            }
            other => panic!("wanted Transport, got {other:?}"),
        }
        assert_eq!(
            Contrary.admin_retire(vec![1]).unwrap_err(),
            AdminError::Unsupported("contrary"),
        );
    }

    #[test]
    fn errors_absorb_layer_locals_and_render() {
        let v: AdminError = VocabError("id 5 is retired".into()).into();
        assert!(v.to_string().contains("id 5 is retired"));
        let s: AdminError =
            SnapshotError::FutureVersion { found: 9, max: 1 }.into();
        assert!(s.to_string().contains('9'), "{s}");
        let r = AdminError::Remote { code: 3, message: "nope".into() };
        assert!(r.to_string().contains("remote error 3"));
    }

    #[test]
    fn op_names_are_stable() {
        assert_eq!(AdminOp::Snapshot.name(), "snapshot");
        assert_eq!(AdminOp::RetireClasses { ids: vec![] }.name(), "retire_classes");
    }
}
