//! Dataset substrates.
//!
//! The paper evaluates on licensed corpora (PennTreeBank, Bnews) and the
//! extreme-classification repository, none of which are redistributable or
//! reachable here (repro band 0). Per DESIGN.md §2 we implement synthetic
//! generators that preserve the properties the paper's comparisons
//! actually exercise:
//!
//! * [`synthlm`] — Zipf–Markov language corpus: heavy-tailed unigram
//!   class frequencies (what separates UNIFORM from softmax-tracking
//!   samplers) plus low-rank bigram structure (so the model has something
//!   to learn and the class-embedding geometry evolves during training).
//! * [`extreme`] — planted-embedding sparse multi-label generator with a
//!   known Bayes-optimal ranking (so PREC@k has a meaningful ceiling).
//! * [`usps_like`] — normalized vectors with a USPS-like cosine spread for
//!   the Table-1 kernel-MSE harness.

pub mod extreme;
pub mod synthlm;
pub mod usps_like;

/// A batch of language-model examples: fixed-length contexts + next-token
/// targets. Layout matches the AOT `train_step` executable's inputs.
#[derive(Clone, Debug, PartialEq)]
pub struct LmBatch {
    /// `batch × seq_len` token ids, row-major.
    pub contexts: Vec<u32>,
    /// `batch` target ids.
    pub targets: Vec<u32>,
    pub batch: usize,
    pub seq_len: usize,
}

impl LmBatch {
    pub fn context(&self, i: usize) -> &[u32] {
        &self.contexts[i * self.seq_len..(i + 1) * self.seq_len]
    }
}

/// A batch of extreme-classification examples: sparse features + one
/// target class (multi-label reduced to multi-class per paper footnote 1).
#[derive(Clone, Debug, PartialEq)]
pub struct SparseBatch {
    /// `batch × nnz` active feature ids, row-major.
    pub features: Vec<u32>,
    /// `batch × nnz` feature values.
    pub values: Vec<f32>,
    /// `batch` target class ids.
    pub targets: Vec<u32>,
    pub batch: usize,
    pub nnz: usize,
}

impl SparseBatch {
    pub fn feature_row(&self, i: usize) -> (&[u32], &[f32]) {
        let s = i * self.nnz;
        (&self.features[s..s + self.nnz], &self.values[s..s + self.nnz])
    }
}
