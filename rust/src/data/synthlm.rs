//! Synthetic Zipf–Markov language corpus (PennTreeBank / Bnews stand-in;
//! DESIGN.md §2).
//!
//! Word ids are frequency-ranked (id 0 = most frequent), drawn from a
//! Zipf(s) unigram prior blended with a low-rank Markov channel: each word
//! belongs to one of `rank` topics, and with probability `markov_weight`
//! the next word is drawn from the *successor topic's* word distribution
//! instead of the prior. The result has (a) natural-language-like
//! heavy-tailed class frequencies and (b) learnable bigram structure, the
//! two properties the paper's sampler comparisons exercise.

use super::LmBatch;
use crate::rng::{AliasTable, Rng, Zipf};

/// Corpus generator + tokenized train/valid splits.
pub struct SynthCorpus {
    pub vocab_size: usize,
    pub train: Vec<u32>,
    pub valid: Vec<u32>,
    /// Empirical unigram counts over the train split (for unigram priors).
    pub unigram: Vec<u64>,
    /// Topic assignment per word (generation ground truth; useful for
    /// diagnostics, not visible to the model).
    pub topic: Vec<u16>,
}

/// Generation parameters.
#[derive(Clone, Debug)]
pub struct SynthLmParams {
    pub vocab_size: usize,
    pub zipf_s: f64,
    pub rank: usize,
    pub markov_weight: f64,
    pub train_tokens: usize,
    pub valid_tokens: usize,
    pub seed: u64,
}

impl Default for SynthLmParams {
    fn default() -> Self {
        Self {
            vocab_size: 10_000,
            zipf_s: 1.0,
            rank: 16,
            markov_weight: 0.7,
            train_tokens: 200_000,
            valid_tokens: 20_000,
            seed: 7,
        }
    }
}

impl SynthCorpus {
    pub fn generate(p: &SynthLmParams) -> Self {
        assert!(p.vocab_size >= 2);
        assert!(p.rank >= 1);
        assert!((0.0..=1.0).contains(&p.markov_weight));
        let mut rng = Rng::seeded(p.seed);
        let n = p.vocab_size;
        let prior = Zipf::new(n, p.zipf_s);

        // Topic structure: word w belongs to topic w % rank; topic z's
        // successor topic is (z+1) % rank; topic z's word distribution is
        // the Zipf prior restricted to its members (renormalized).
        let topic: Vec<u16> = (0..n).map(|w| (w % p.rank) as u16).collect();
        let pmf = prior.pmf();
        let mut topic_tables: Vec<AliasTable> = Vec::with_capacity(p.rank);
        let mut topic_members: Vec<Vec<u32>> = vec![Vec::new(); p.rank];
        for w in 0..n {
            topic_members[w % p.rank].push(w as u32);
        }
        for z in 0..p.rank {
            let weights: Vec<f64> =
                topic_members[z].iter().map(|&w| pmf[w as usize]).collect();
            topic_tables.push(AliasTable::new(&weights));
        }

        let total = p.train_tokens + p.valid_tokens;
        let mut tokens = Vec::with_capacity(total);
        let mut prev = prior.sample(&mut rng) as u32;
        tokens.push(prev);
        while tokens.len() < total {
            let next = if rng.bernoulli(p.markov_weight) {
                let z = (topic[prev as usize] as usize + 1) % p.rank;
                let k = topic_tables[z].sample(&mut rng);
                topic_members[z][k]
            } else {
                prior.sample(&mut rng) as u32
            };
            tokens.push(next);
            prev = next;
        }

        let valid = tokens.split_off(p.train_tokens);
        let mut unigram = vec![0u64; n];
        for &t in &tokens {
            unigram[t as usize] += 1;
        }
        Self { vocab_size: n, train: tokens, valid, unigram, topic }
    }

    /// Unigram prior with add-one smoothing (for the unigram sampler).
    pub fn unigram_prior(&self) -> Vec<f64> {
        self.unigram.iter().map(|&c| (c + 1) as f64).collect()
    }

    /// Iterator over `(context, target)` training windows with the given
    /// epoch's deterministic shuffled order.
    pub fn batches<'a>(
        &'a self,
        split: Split,
        seq_len: usize,
        batch: usize,
        epoch_seed: u64,
    ) -> LmBatchIter<'a> {
        let tokens = match split {
            Split::Train => &self.train,
            Split::Valid => &self.valid,
        };
        assert!(
            tokens.len() > seq_len,
            "split too small for seq_len {seq_len}"
        );
        let num_windows = tokens.len() - seq_len;
        let mut order: Vec<usize> = (0..num_windows).collect();
        if matches!(split, Split::Train) {
            Rng::seeded(epoch_seed).shuffle(&mut order);
        }
        LmBatchIter { tokens, order, pos: 0, seq_len, batch }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
}

/// Iterator yielding [`LmBatch`]es; the final partial batch is dropped
/// (fixed shapes are required by the AOT executables).
pub struct LmBatchIter<'a> {
    tokens: &'a [u32],
    order: Vec<usize>,
    pos: usize,
    seq_len: usize,
    batch: usize,
}

impl<'a> Iterator for LmBatchIter<'a> {
    type Item = LmBatch;

    fn next(&mut self) -> Option<LmBatch> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let mut contexts = Vec::with_capacity(self.batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch);
        for k in 0..self.batch {
            let start = self.order[self.pos + k];
            contexts.extend_from_slice(&self.tokens[start..start + self.seq_len]);
            targets.push(self.tokens[start + self.seq_len]);
        }
        self.pos += self.batch;
        Some(LmBatch { contexts, targets, batch: self.batch, seq_len: self.seq_len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SynthLmParams {
        SynthLmParams {
            vocab_size: 100,
            zipf_s: 1.0,
            rank: 4,
            markov_weight: 0.6,
            train_tokens: 5000,
            valid_tokens: 500,
            seed: 1,
        }
    }

    #[test]
    fn sizes_and_ranges() {
        let c = SynthCorpus::generate(&small());
        assert_eq!(c.train.len(), 5000);
        assert_eq!(c.valid.len(), 500);
        assert!(c.train.iter().all(|&t| (t as usize) < 100));
        assert!(c.valid.iter().all(|&t| (t as usize) < 100));
    }

    #[test]
    fn determinism() {
        let a = SynthCorpus::generate(&small());
        let b = SynthCorpus::generate(&small());
        assert_eq!(a.train, b.train);
        let mut p2 = small();
        p2.seed = 2;
        let c = SynthCorpus::generate(&p2);
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn frequencies_are_zipf_skewed() {
        let c = SynthCorpus::generate(&SynthLmParams {
            vocab_size: 200,
            train_tokens: 100_000,
            ..small()
        });
        // Head words (ids < 20) should dominate tail words (ids >= 150).
        let head: u64 = c.unigram[..20].iter().sum();
        let tail: u64 = c.unigram[150..].iter().sum();
        assert!(
            head > 5 * tail.max(1),
            "head {head} vs tail {tail} — not Zipfian"
        );
    }

    #[test]
    fn markov_structure_is_present() {
        // Successor-topic transition should beat the unigram rate:
        // P(topic(w_{t+1}) = topic(w_t)+1) ≫ 1/rank.
        let p = SynthLmParams {
            markov_weight: 0.8,
            train_tokens: 50_000,
            ..small()
        };
        let c = SynthCorpus::generate(&p);
        let mut hits = 0usize;
        let mut total = 0usize;
        for w in c.train.windows(2) {
            let zt = c.topic[w[0] as usize] as usize;
            let zn = c.topic[w[1] as usize] as usize;
            if zn == (zt + 1) % p.rank {
                hits += 1;
            }
            total += 1;
        }
        let frac = hits as f64 / total as f64;
        assert!(
            frac > 0.5,
            "successor-topic fraction {frac} too low — no Markov structure"
        );
    }

    #[test]
    fn batches_cover_and_shapes() {
        let c = SynthCorpus::generate(&small());
        let mut count = 0;
        for b in c.batches(Split::Train, 8, 16, 0) {
            assert_eq!(b.contexts.len(), 16 * 8);
            assert_eq!(b.targets.len(), 16);
            assert_eq!(b.context(3).len(), 8);
            count += 1;
        }
        assert_eq!(count, (5000 - 8) / 16);
    }

    #[test]
    fn train_batches_shuffle_by_epoch() {
        let c = SynthCorpus::generate(&small());
        let b0 = c.batches(Split::Train, 4, 8, 0).next().unwrap();
        let b1 = c.batches(Split::Train, 4, 8, 1).next().unwrap();
        assert_ne!(b0, b1, "different epochs must shuffle differently");
        let b0_again = c.batches(Split::Train, 4, 8, 0).next().unwrap();
        assert_eq!(b0, b0_again, "same epoch must be deterministic");
    }

    #[test]
    fn valid_batches_are_sequential() {
        let c = SynthCorpus::generate(&small());
        let a = c.batches(Split::Valid, 4, 8, 0).next().unwrap();
        let b = c.batches(Split::Valid, 4, 8, 99).next().unwrap();
        assert_eq!(a, b, "validation order must not depend on epoch seed");
    }

    #[test]
    fn unigram_prior_strictly_positive() {
        let c = SynthCorpus::generate(&small());
        assert!(c.unigram_prior().iter().all(|&w| w > 0.0));
    }
}
