//! USPS-like embedding pairs for the Table-1 kernel-MSE harness.
//!
//! USPS digits are nonnegative pixel vectors; after L2 normalization their
//! pairwise cosines concentrate well above 0 (images share background
//! structure). The MSE of a kernel approximation over such pairs depends
//! only on that cosine distribution, so we synthesize unit vectors as
//! `normalize(μ + σ·g)` around a shared direction μ with per-class jitter,
//! which reproduces a USPS-like cosine spread (mean ≈ 0.55, sd ≈ 0.2).

use crate::linalg::{l2_normalize, unit_vector};
#[cfg(test)]
use crate::linalg::cosine;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct UspsLikeParams {
    pub dim: usize,
    /// Number of synthetic "digit classes" sharing a cluster direction.
    pub classes: usize,
    /// Spread of samples around their class direction.
    pub within_sigma: f32,
    /// Spread of class directions around the global mean.
    pub between_sigma: f32,
}

impl Default for UspsLikeParams {
    fn default() -> Self {
        // d = 256 matches USPS (16×16); sigmas tuned so that pairwise
        // cosines land in the USPS-like band (see tests).
        Self { dim: 256, classes: 10, within_sigma: 0.55, between_sigma: 0.9 }
    }
}

/// Generate `n` unit vectors with USPS-like cosine geometry.
pub fn vectors(p: &UspsLikeParams, n: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    let global = unit_vector(rng, p.dim);
    let class_dirs: Vec<Vec<f32>> = (0..p.classes)
        .map(|_| {
            let mut v: Vec<f32> = global
                .iter()
                .map(|&g| g + p.between_sigma * rng.gaussian_f32() / (p.dim as f32).sqrt())
                .collect();
            l2_normalize(&mut v);
            v
        })
        .collect();
    (0..n)
        .map(|i| {
            let c = &class_dirs[i % p.classes];
            let mut v: Vec<f32> = c
                .iter()
                .map(|&ci| ci + p.within_sigma * rng.gaussian_f32() / (p.dim as f32).sqrt())
                .collect();
            l2_normalize(&mut v);
            v
        })
        .collect()
}

/// Generate `n` random (h, c) pairs (distinct indices) from the vector
/// pool, as used by the Table-1 harness.
pub fn pairs(
    p: &UspsLikeParams,
    pool: usize,
    n: usize,
    rng: &mut Rng,
) -> Vec<(Vec<f32>, Vec<f32>)> {
    let vs = vectors(p, pool, rng);
    (0..n)
        .map(|_| {
            let i = rng.index(pool);
            let mut j = rng.index(pool);
            while j == i {
                j = rng.index(pool);
            }
            (vs[i].clone(), vs[j].clone())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vectors_are_unit_norm() {
        let mut rng = Rng::seeded(151);
        let vs = vectors(&UspsLikeParams::default(), 50, &mut rng);
        for v in &vs {
            let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn cosine_spread_is_usps_like() {
        let mut rng = Rng::seeded(152);
        let vs = vectors(&UspsLikeParams::default(), 200, &mut rng);
        let mut cosines = Vec::new();
        for i in 0..50 {
            for j in (i + 1)..50 {
                cosines.push(cosine(&vs[i], &vs[j]) as f64);
            }
        }
        let mean = cosines.iter().sum::<f64>() / cosines.len() as f64;
        let var = cosines.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
            / cosines.len() as f64;
        assert!(
            (0.3..0.85).contains(&mean),
            "mean cosine {mean} outside USPS-like band"
        );
        assert!(var.sqrt() > 0.03, "cosine spread too tight: {}", var.sqrt());
    }

    #[test]
    fn pairs_are_distinct_and_sized() {
        let mut rng = Rng::seeded(153);
        let ps = pairs(&UspsLikeParams::default(), 100, 30, &mut rng);
        assert_eq!(ps.len(), 30);
        for (a, b) in &ps {
            assert_eq!(a.len(), 256);
            assert_ne!(a, b);
        }
    }
}
