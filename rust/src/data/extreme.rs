//! Planted-embedding extreme-classification generator (AmazonCat-13K /
//! Delicious-200K / WikiLSHTC stand-in; DESIGN.md §2).
//!
//! Generative model with a known Bayes-optimal ranking:
//!
//! 1. ground-truth class vectors `c*_1..c*_n` on the unit sphere of ℝ^{d*};
//! 2. each feature `f ∈ [v]` carries a latent vector `a_f` (gaussian);
//! 3. an example draws `nnz` feature ids from a Zipf prior, sums their
//!    latents (+ noise) into a normalized latent `u`;
//! 4. its label set is the top `labels_per_example` classes by `uᵀc*_i`
//!    over a random candidate subset (exact top-k over all n for modest n).
//!
//! Training pairs follow the paper's multi-label→multi-class reduction
//! (footnote 1): each step samples one positive label as the target.
//! PREC@k against the held-out label sets has a meaningful ceiling because
//! the optimal predictor recovers `u ↦ top-k(uᵀc*)`.

use super::SparseBatch;
use crate::linalg::{dot, l2_normalize, Matrix};
use crate::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct ExtremeParams {
    pub num_classes: usize,
    pub feature_dim: usize,
    /// Latent dimension d* of the planted model.
    pub latent_dim: usize,
    /// Active features per example.
    pub nnz: usize,
    pub labels_per_example: usize,
    pub train_examples: usize,
    pub test_examples: usize,
    /// Gaussian noise std added to the latent before ranking.
    pub noise: f64,
    /// Candidate-subset size for label selection (caps generation cost at
    /// large n; `0` ⇒ rank all classes).
    pub candidates: usize,
    /// Topic clusters: each example draws all of its features from one
    /// cluster's feature pool, so the latent distribution has `clusters`
    /// modes and the induced label distribution concentrates — without
    /// this, labels spread over nearly every class and PREC@k is
    /// unlearnable at our reduced train-set sizes (the paper's datasets
    /// have 10⁵–10⁶ examples). `0` disables clustering.
    pub clusters: usize,
    pub seed: u64,
}

impl Default for ExtremeParams {
    fn default() -> Self {
        Self {
            num_classes: 1000,
            feature_dim: 8192,
            latent_dim: 32,
            nnz: 16,
            labels_per_example: 3,
            train_examples: 20_000,
            test_examples: 2000,
            noise: 0.3,
            candidates: 0,
            clusters: 200,
            seed: 11,
        }
    }
}

/// One example: sparse features + ground-truth label set.
#[derive(Clone, Debug)]
pub struct Example {
    pub features: Vec<u32>,
    pub values: Vec<f32>,
    pub labels: Vec<u32>,
}

pub struct ExtremeDataset {
    pub params: ExtremeParams,
    pub train: Vec<Example>,
    pub test: Vec<Example>,
    /// Planted class vectors (diagnostics / Bayes ceiling only).
    pub true_classes: Matrix,
    /// Per-class positive counts in train (for unigram priors).
    pub class_freq: Vec<u64>,
}

impl ExtremeDataset {
    pub fn generate(p: &ExtremeParams) -> Self {
        assert!(p.labels_per_example >= 1);
        assert!(p.nnz >= 1 && p.nnz <= p.feature_dim);
        let mut rng = Rng::seeded(p.seed);
        let true_classes =
            Matrix::randn(&mut rng, p.num_classes, p.latent_dim)
                .l2_normalized_rows();
        let feat_latents =
            Matrix::randn_scaled(&mut rng, p.feature_dim, p.latent_dim, 1.0);
        let feat_prior = Zipf::new(p.feature_dim, 1.0);
        // Cluster-restricted feature prior: cluster c owns features
        // {f : f ≡ c (mod clusters)}; within a pool, rank-Zipf.
        let clusters = p.clusters.min(p.feature_dim / p.nnz.max(1)).max(0);
        let cluster_prior =
            if clusters > 0 { Some(Zipf::new(clusters, 1.0)) } else { None };
        let pool_size = if clusters > 0 {
            p.feature_dim / clusters
        } else {
            0
        };
        let pool_rank =
            if clusters > 0 { Some(Zipf::new(pool_size, 1.0)) } else { None };
        // Cluster centers on the latent sphere: in clustered mode the
        // example latent is center + noise, so the induced label sets
        // concentrate to a few per cluster (learnable from the
        // cluster-exclusive features).
        let centers = if clusters > 0 {
            Some(
                Matrix::randn(&mut rng, clusters, p.latent_dim)
                    .l2_normalized_rows(),
            )
        } else {
            None
        };
        // Per-cluster label shortlists: the top classes by center·c*.
        // Example latents are center + small noise, so their true top-k
        // lies inside the shortlist with overwhelming probability — this
        // replaces a full n-way ranking per example with a 256-way one
        // (a random candidate subset would destroy the planted structure:
        // different examples of one cluster would rank disjoint subsets).
        let shortlist_len = (64 * p.labels_per_example).clamp(64, 512).min(p.num_classes);
        let shortlists: Option<Vec<Vec<u32>>> = centers.as_ref().map(|ctr| {
            (0..clusters)
                .map(|c| {
                    let mut scored: Vec<(f32, u32)> = (0..p.num_classes)
                        .map(|i| {
                            (dot(ctr.row(c), true_classes.row(i)), i as u32)
                        })
                        .collect();
                    scored.select_nth_unstable_by(
                        shortlist_len - 1,
                        |a, b| b.0.partial_cmp(&a.0).unwrap(),
                    );
                    scored.truncate(shortlist_len);
                    scored.into_iter().map(|(_, i)| i).collect()
                })
                .collect()
        });

        let gen_one = |rng: &mut Rng| -> Example {
            // Distinct feature ids, drawn from one cluster's pool (or the
            // global Zipf prior when clustering is disabled).
            let mut feats = Vec::with_capacity(p.nnz);
            let mut seen = std::collections::HashSet::new();
            let mut u = vec![0.0f32; p.latent_dim];
            let mut cluster_of_example: Option<usize> = None;
            match (&cluster_prior, &pool_rank, &centers) {
                (Some(cp), Some(pr), Some(ctr)) => {
                    let c = cp.sample(rng) as u32;
                    cluster_of_example = Some(c as usize);
                    while feats.len() < p.nnz {
                        let rank = pr.sample(rng) as u32;
                        let f = rank * clusters as u32 + c;
                        if seen.insert(f) {
                            feats.push(f);
                        }
                    }
                    // Latent = cluster center + noise. `noise` is the
                    // expected *norm* of the perturbation relative to the
                    // unit center, so scale per-coordinate by 1/√d*.
                    let per_coord = p.noise / (p.latent_dim as f64).sqrt();
                    for (ui, &ci) in u.iter_mut().zip(ctr.row(c as usize)) {
                        *ui = ci + (rng.gaussian() * per_coord) as f32;
                    }
                }
                _ => {
                    while feats.len() < p.nnz {
                        let f = feat_prior.sample(rng) as u32;
                        if seen.insert(f) {
                            feats.push(f);
                        }
                    }
                    // Latent = normalized sum of feature latents + noise.
                    for &f in &feats {
                        for (ui, ai) in
                            u.iter_mut().zip(feat_latents.row(f as usize))
                        {
                            *ui += ai;
                        }
                    }
                    for ui in u.iter_mut() {
                        *ui += (rng.gaussian() * p.noise) as f32;
                    }
                }
            }
            l2_normalize(&mut u);
            let values = vec![1.0f32; p.nnz];
            // Label set = top-k classes by u·c*: over the cluster's
            // shortlist when clustered, else over candidates / all n.
            let candidates: Vec<usize> = match (&shortlists, cluster_of_example) {
                (Some(sl), Some(c)) => {
                    sl[c].iter().map(|&i| i as usize).collect()
                }
                _ if p.candidates == 0 || p.candidates >= p.num_classes => {
                    (0..p.num_classes).collect()
                }
                _ => {
                    let mut c =
                        rng.sample_distinct(p.num_classes, p.candidates);
                    c.sort_unstable();
                    c
                }
            };
            let mut scored: Vec<(f32, u32)> = candidates
                .iter()
                .map(|&i| (dot(&u, true_classes.row(i)), i as u32))
                .collect();
            let k = p.labels_per_example.min(scored.len());
            scored.select_nth_unstable_by(k - 1, |a, b| {
                b.0.partial_cmp(&a.0).unwrap()
            });
            scored.truncate(k);
            let labels: Vec<u32> = scored.into_iter().map(|(_, i)| i).collect();
            Example { features: feats, values, labels }
        };

        let train: Vec<Example> =
            (0..p.train_examples).map(|_| gen_one(&mut rng)).collect();
        let test: Vec<Example> =
            (0..p.test_examples).map(|_| gen_one(&mut rng)).collect();

        let mut class_freq = vec![0u64; p.num_classes];
        for ex in &train {
            for &l in &ex.labels {
                class_freq[l as usize] += 1;
            }
        }
        Self { params: p.clone(), train, test, true_classes, class_freq }
    }

    /// Assemble a training batch: one uniformly-drawn positive label per
    /// example (multi-label → multi-class reduction).
    pub fn train_batch(
        &self,
        indices: &[usize],
        rng: &mut Rng,
    ) -> SparseBatch {
        let p = &self.params;
        let b = indices.len();
        let mut features = Vec::with_capacity(b * p.nnz);
        let mut values = Vec::with_capacity(b * p.nnz);
        let mut targets = Vec::with_capacity(b);
        for &i in indices {
            let ex = &self.train[i];
            features.extend_from_slice(&ex.features);
            values.extend_from_slice(&ex.values);
            targets.push(ex.labels[rng.index(ex.labels.len())]);
        }
        SparseBatch { features, values, targets, batch: b, nnz: p.nnz }
    }

    /// Smoothed unigram prior over classes.
    pub fn class_prior(&self) -> Vec<f64> {
        self.class_freq.iter().map(|&c| (c + 1) as f64).collect()
    }

    /// Bayes-optimal PREC@k on the test split (score classes by the
    /// planted `uᵀc*` with the noiseless latent reconstructed from
    /// features) — the ceiling our trained models chase. Noise in label
    /// generation keeps this below 1.
    pub fn bayes_prec_at_k(&self, k: usize) -> f64 {
        // Reconstruct each test latent from its features via the same
        // generator (without noise) — we regenerate feat latents from the
        // stored seed to stay self-contained.
        let p = &self.params;
        let mut rng = Rng::seeded(p.seed);
        let _classes =
            Matrix::randn(&mut rng, p.num_classes, p.latent_dim);
        let feat_latents =
            Matrix::randn_scaled(&mut rng, p.feature_dim, p.latent_dim, 1.0);
        // Mirror generate()'s RNG consumption order exactly.
        let clusters = p.clusters.min(p.feature_dim / p.nnz.max(1));
        let centers = if clusters > 0 {
            Some(
                Matrix::randn(&mut rng, clusters, p.latent_dim)
                    .l2_normalized_rows(),
            )
        } else {
            None
        };
        let mut hits = 0usize;
        let mut total = 0usize;
        for ex in &self.test {
            let mut u = vec![0.0f32; p.latent_dim];
            if let Some(ctr) = &centers {
                // Cluster id is recoverable from any feature (pools are
                // residue classes mod `clusters`).
                let c = ex.features[0] as usize % clusters;
                u.copy_from_slice(ctr.row(c));
            } else {
                for &f in &ex.features {
                    for (ui, ai) in
                        u.iter_mut().zip(feat_latents.row(f as usize))
                    {
                        *ui += ai;
                    }
                }
            }
            l2_normalize(&mut u);
            let mut scored: Vec<(f32, u32)> = (0..p.num_classes)
                .map(|i| (dot(&u, self.true_classes.row(i)), i as u32))
                .collect();
            let kk = k.min(scored.len());
            scored.select_nth_unstable_by(kk - 1, |a, b| {
                b.0.partial_cmp(&a.0).unwrap()
            });
            scored.truncate(kk);
            let labelset: std::collections::HashSet<u32> =
                ex.labels.iter().copied().collect();
            hits += scored.iter().filter(|(_, i)| labelset.contains(i)).count();
            total += kk;
        }
        hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ExtremeParams {
        ExtremeParams {
            num_classes: 50,
            feature_dim: 500,
            latent_dim: 8,
            nnz: 6,
            labels_per_example: 3,
            train_examples: 300,
            test_examples: 100,
            noise: 0.2,
            candidates: 0,
            clusters: 10,
            seed: 3,
        }
    }

    #[test]
    fn shapes_and_ranges() {
        let d = ExtremeDataset::generate(&small());
        assert_eq!(d.train.len(), 300);
        assert_eq!(d.test.len(), 100);
        for ex in d.train.iter().chain(d.test.iter()) {
            assert_eq!(ex.features.len(), 6);
            assert_eq!(ex.labels.len(), 3);
            assert!(ex.features.iter().all(|&f| (f as usize) < 500));
            assert!(ex.labels.iter().all(|&l| (l as usize) < 50));
            let set: std::collections::HashSet<_> = ex.features.iter().collect();
            assert_eq!(set.len(), 6, "duplicate features");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ExtremeDataset::generate(&small());
        let b = ExtremeDataset::generate(&small());
        assert_eq!(a.train[0].features, b.train[0].features);
        assert_eq!(a.train[0].labels, b.train[0].labels);
    }

    #[test]
    fn bayes_ceiling_is_high() {
        // With modest noise the planted ranking should recover most labels.
        let d = ExtremeDataset::generate(&small());
        let prec1 = d.bayes_prec_at_k(1);
        assert!(prec1 > 0.5, "bayes PREC@1 too low: {prec1}");
        // And PREC@k decreases in k (labels_per_example = 3 < ranked 5).
        let prec5 = d.bayes_prec_at_k(5);
        assert!(prec5 <= prec1 + 1e-9);
    }

    #[test]
    fn train_batch_targets_are_positive_labels() {
        let d = ExtremeDataset::generate(&small());
        let mut rng = Rng::seeded(9);
        let batch = d.train_batch(&[0, 1, 2, 3], &mut rng);
        assert_eq!(batch.batch, 4);
        for i in 0..4 {
            assert!(d.train[i].labels.contains(&batch.targets[i]));
            let (f, v) = batch.feature_row(i);
            assert_eq!(f, &d.train[i].features[..]);
            assert_eq!(v.len(), 6);
        }
    }

    #[test]
    fn class_prior_positive_everywhere() {
        let d = ExtremeDataset::generate(&small());
        assert!(d.class_prior().iter().all(|&w| w > 0.0));
        assert_eq!(d.class_prior().len(), 50);
    }

    #[test]
    fn candidate_capping_works() {
        let mut p = small();
        p.candidates = 10;
        let d = ExtremeDataset::generate(&p);
        assert_eq!(d.train.len(), 300);
    }
}
