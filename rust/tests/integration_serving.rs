//! Concurrent-correctness tests for the serving subsystem (no artifacts
//! needed): served draws vs the offline sampler under chi-square, the
//! Σq = 1 invariant sampled mid-swap under a writer applying updates in
//! a loop, seeded determinism regardless of thread schedule, and the
//! trainer-style no-stale-epoch contract of the double-buffered service.

use rfsoftmax::featmap::RffMap;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{Sampler, ServeSampler, ShardedKernelSampler};
use rfsoftmax::serving::{BatcherOptions, MicroBatcher, SamplerServer};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn sharded_rff(
    n: usize,
    d: usize,
    shards: usize,
    seed: u64,
) -> ShardedKernelSampler<RffMap> {
    let mut rng = Rng::seeded(seed);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let map = RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1));
    ShardedKernelSampler::with_map(&classes, map, shards, "rff-sharded")
}

/// Multi-reader chi-square: draws served through the batcher from many
/// threads must follow the *offline* sampler's distribution exactly.
#[test]
fn served_draws_match_offline_sampler_chi_square() {
    let n = 64;
    let d = 8;
    let offline = sharded_rff(n, d, 4, 1000);
    let serve: Box<dyn ServeSampler> = offline.fork().unwrap();
    let (server, _writer) = SamplerServer::new(serve);
    let batcher = Arc::new(MicroBatcher::spawn(
        server,
        BatcherOptions { max_batch: 16, max_wait: Duration::from_micros(200) },
    ));

    let mut rng = Rng::seeded(1001);
    let h = unit_vector(&mut rng, d);
    let threads = 4;
    let per_thread = 1500;
    let m = 8;
    let counts: Vec<Vec<usize>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let batcher = Arc::clone(&batcher);
                let h = h.clone();
                scope.spawn(move || {
                    let mut local = vec![0usize; n];
                    for i in 0..per_thread {
                        let reply =
                            batcher.sample(&h, m, (t * 1_000_000 + i) as u64);
                        assert_eq!(reply.draw.len(), m);
                        assert_eq!(reply.epoch, 0);
                        for &id in &reply.draw.ids {
                            local[id as usize] += 1;
                        }
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let trials = threads * per_thread * m;
    let mut total_counts = vec![0usize; n];
    for c in &counts {
        for (tc, x) in total_counts.iter_mut().zip(c) {
            *tc += x;
        }
    }
    for i in 0..n {
        let q = offline.probability(&h, i);
        let expect = q * trials as f64;
        let sd = (trials as f64 * q * (1.0 - q)).sqrt().max(1.0);
        assert!(
            (total_counts[i] as f64 - expect).abs() <= 5.0 * sd + 3.0,
            "class {i}: served count {} vs offline expectation {expect:.1} \
             (q = {q:.5})",
            total_counts[i]
        );
    }
}

/// Σq ≈ 1 sampled mid-swap: readers repeatedly pin snapshots and sum the
/// full distribution while a writer applies update batches and publishes
/// in a tight loop. Epochs must also be monotone per reader.
#[test]
fn unit_mass_invariant_holds_mid_swap_under_writer_loop() {
    let n = 48;
    let d = 6;
    let offline = sharded_rff(n, d, 4, 1100);
    let (server, mut writer) = SamplerServer::new(offline.fork().unwrap());
    let done = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..4)
        .map(|r| {
            let server = server.clone();
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut rng = Rng::seeded(1101 + r);
                let h = unit_vector(&mut rng, d);
                let mut last_epoch = 0u64;
                let mut checks = 0usize;
                while !done.load(Ordering::Relaxed) {
                    let snap = server.snapshot();
                    assert!(snap.epoch() >= last_epoch, "epoch regressed");
                    last_epoch = snap.epoch();
                    let total: f64 = (0..n)
                        .map(|i| snap.sampler().probability(&h, i))
                        .sum();
                    assert!(
                        (total - 1.0).abs() < 1e-6,
                        "Σq = {total} at epoch {}",
                        snap.epoch()
                    );
                    checks += 1;
                }
                checks
            })
        })
        .collect();

    let mut rng = Rng::seeded(1102);
    for step in 0..60u32 {
        let ids: Vec<u32> = vec![(step % 47) as u32, 47];
        let mut emb = Matrix::zeros(2, d);
        for r in 0..2 {
            let v = unit_vector(&mut rng, d);
            emb.row_mut(r).copy_from_slice(&v);
        }
        writer.apply_updates(ids, emb);
        writer.publish();
    }
    done.store(true, Ordering::Relaxed);
    let mut total_checks = 0usize;
    for h in readers {
        total_checks += h.join().unwrap();
    }
    assert!(total_checks > 0, "readers never ran");
    assert_eq!(server.epoch(), 60);
}

/// Seeded determinism of served draws regardless of thread schedule: the
/// same (seed, query, epoch) request yields the identical draw whether it
/// is served alone, in a coalesced batch, or re-run later — submission
/// order and coalescing never leak into the result.
#[test]
fn served_draws_are_seed_deterministic_across_schedules() {
    let n = 56;
    let d = 8;
    let offline = sharded_rff(n, d, 4, 1200);
    let m = 6;
    let probes = 24usize;
    let mut rng = Rng::seeded(1201);
    let queries: Vec<Vec<f32>> =
        (0..probes).map(|_| unit_vector(&mut rng, d)).collect();

    // Run the same probe set through three very different schedules.
    let run = |threads: usize, max_batch: usize| -> Vec<Vec<u32>> {
        let (server, _writer) = SamplerServer::new(offline.fork().unwrap());
        let batcher = Arc::new(MicroBatcher::spawn(
            server,
            BatcherOptions {
                max_batch,
                max_wait: Duration::from_millis(1),
            },
        ));
        let mut out: Vec<Vec<u32>> = vec![Vec::new(); probes];
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let batcher = Arc::clone(&batcher);
                    let queries = &queries;
                    scope.spawn(move || {
                        let mut got = Vec::new();
                        let mut i = t;
                        while i < probes {
                            let reply = batcher.sample(
                                &queries[i],
                                m,
                                0xABCD + i as u64,
                            );
                            got.push((i, reply.draw.ids));
                            i += threads;
                        }
                        got
                    })
                })
                .collect();
            for h in handles {
                for (i, ids) in h.join().unwrap() {
                    out[i] = ids;
                }
            }
        });
        out
    };

    let serial = run(1, 1); // one reader, never coalesced
    let batched = run(1, 32); // one reader, aggressive coalescing
    let threaded = run(4, 32); // racing readers, aggressive coalescing
    assert_eq!(serial, batched, "coalescing changed served draws");
    assert_eq!(serial, threaded, "thread schedule changed served draws");
}

/// Trainer-shaped no-stale-epoch contract: a double-buffered service that
/// stages updates asynchronously must serve draw t+1 from a state that
/// includes step t's updates — byte-identical to a synchronous service
/// with the same seeds (the sharded fork is stream-exact, so ANY stale
/// read would diverge the id streams).
#[test]
fn double_buffered_updates_land_before_next_draw_end_to_end() {
    use rfsoftmax::coordinator::SamplerService;
    let n = 96;
    let d = 8;
    let m = 12;
    let build = || -> Box<dyn Sampler> { Box::new(sharded_rff(n, d, 4, 1300)) };
    let mut direct = SamplerService::new(build(), m, Rng::seeded(1301));
    let mut served =
        SamplerService::new_double_buffered(build(), m, Rng::seeded(1301))
            .expect("sharded rff must fork");

    let mut data_rng = Rng::seeded(1302);
    for step in 1..=12u64 {
        // Draw (the served backend publishes staged updates first).
        let bsz = 8;
        let mut h = Matrix::zeros(bsz, d);
        for b in 0..bsz {
            let v = unit_vector(&mut data_rng, d);
            h.row_mut(b).copy_from_slice(&v);
        }
        let targets: Vec<u32> = (0..bsz as u32).collect();
        let pd = direct.draw_batch(&h, &targets);
        let ps = served.draw_batch(&h, &targets);
        assert_eq!(
            pd.ids, ps.ids,
            "step {step}: stale-epoch read (draw streams diverged)"
        );
        assert_eq!(pd.adjust, ps.adjust, "step {step}: adjustments diverged");

        // Simulate the optimizer touching a batch of classes, then the
        // tree propagation: synchronous for `direct`, staged for `served`
        // (overlapping the next phase).
        let rows: Vec<usize> =
            (0..10).map(|j| ((step as usize * 17 + j * 7) % n)).collect();
        let mut uniq = rows.clone();
        uniq.sort_unstable();
        uniq.dedup();
        let mut emb = Matrix::zeros(uniq.len(), d);
        for r in 0..uniq.len() {
            let v = unit_vector(&mut data_rng, d);
            emb.row_mut(r).copy_from_slice(&v);
        }
        direct.update_classes(&uniq, &emb);
        served.update_classes(&uniq, &emb);
    }
    // Final consistency: one more draw forces the last publish, after
    // which the pinned snapshot's full distribution matches the direct
    // sampler's exactly.
    let h = Matrix::zeros(1, d);
    let _ = direct.draw_batch(&h, &[0]);
    let _ = served.draw_batch(&h, &[0]);
    let mut rng = Rng::seeded(1303);
    let probe = unit_vector(&mut rng, d);
    for i in 0..n {
        let a = direct.sampler().probability(&probe, i);
        let b = served.sampler().probability(&probe, i);
        assert!(
            (a - b).abs() < 1e-12 * a.max(b).max(1e-12),
            "class {i}: direct {a} vs served {b}"
        );
    }
    let stats = served.serving_stats().unwrap();
    assert_eq!(stats.publishes, 12, "one swap per staged step");
    assert_eq!(stats.epoch, 12);
}

/// top_k served through the server matches the offline ranking.
#[test]
fn served_top_k_matches_offline_ranking() {
    let n = 72;
    let d = 8;
    let offline = sharded_rff(n, d, 4, 1400);
    let (server, _writer) = SamplerServer::new(offline.fork().unwrap());
    let mut rng = Rng::seeded(1401);
    for _ in 0..5 {
        let h = unit_vector(&mut rng, d);
        let served = server.top_k(&h, 10);
        let offline_top = offline.top_k(&h, 10);
        assert_eq!(served.len(), 10);
        for (j, ((si, sq), (oi, oq))) in
            served.iter().zip(&offline_top).enumerate()
        {
            assert_eq!(si, oi, "rank {j}");
            assert!((sq - oq).abs() < 1e-12 * oq.max(1e-12), "rank {j}");
        }
    }
}
