//! L5 cluster integration: three real in-process `TransportServer`
//! replicas (each owning one consistent-hash shard of the class
//! universe) driven through a [`ClusterRouter`]. Covers the four
//! cluster contracts end to end:
//!
//! 1. merged sample draws are χ²-consistent with a single-node sampler
//!    over the union vocabulary, and per-draw / probability / top-k
//!    merges match the union sampler's answers (mass-weighted merge is
//!    exact, not approximate);
//! 2. churn through the router converges every replica to the same
//!    live set and the same epoch-sequence cursor;
//! 3. killing a replica mid-load fails over without wedging — reads
//!    keep serving from the survivors, owner-exclusive lookups fail
//!    with typed errors, and replication flush terminates with the
//!    loss recorded;
//! 4. hedged requests never double-count in stats reconciliation —
//!    the straggler's duplicate is visible server-side while the
//!    cluster's logical request counter moves once;
//! 5. a killed replica rejoins via snapshot-bootstrap — chunked
//!    `STATE_SNAPSHOT` fetch before the crash, restore into a fresh
//!    skeleton, replay of the parked replication tail — and converges
//!    to the shared cursor with zero lost churn ops.

use rfsoftmax::cluster::{
    shard_partition, Cluster, ClusterError, ClusterOptions,
};
use rfsoftmax::featmap::RffMap;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{Sampler, ShardedKernelSampler};
use rfsoftmax::serving::{
    BatcherOptions, MicroBatcher, SamplerServer, SharedWriterAdmin,
};
use rfsoftmax::transport::TransportServer;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const REPLICAS: usize = 3;
const VNODES: usize = 64;

fn sock_path(tag: &str, replica: usize) -> PathBuf {
    std::env::temp_dir().join(format!(
        "rfsm-cluster-{}-{tag}-{replica}.sock",
        std::process::id()
    ))
}

/// The RFF feature map every sampler in one fixture shares: replicas
/// and the union reference must embed with identical features for the
/// mass-weighted merge to be exactly the union distribution.
fn feature_map(d: usize, seed: u64) -> RffMap {
    RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1))
}

struct Replica {
    server: SamplerServer,
    batcher: Arc<MicroBatcher>,
    /// `Option` so a test can kill one replica by dropping its
    /// listener (and with it every accepted connection).
    transport: Option<TransportServer>,
}

/// One shard-replicated cluster over a shared class matrix, plus the
/// single-node union reference built over the same rows and feature
/// map.
///
/// Field order matters: `cluster` must drop before `replicas` so the
/// replication worker's admin connections close before the transport
/// servers join their connection threads.
struct ClusterFixture {
    reference: ShardedKernelSampler<RffMap>,
    cluster: Cluster,
    replicas: Vec<Replica>,
}

fn fixture(
    n: usize,
    d: usize,
    seed: u64,
    tag: &str,
    opts_for: impl Fn(usize) -> BatcherOptions,
    copts: ClusterOptions,
) -> ClusterFixture {
    let mut rng = Rng::seeded(seed);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let reference = ShardedKernelSampler::with_map(
        &classes,
        feature_map(d, seed),
        2,
        "rff-sharded",
    );
    let partitions = shard_partition(n, REPLICAS, VNODES);
    let mut replicas = Vec::with_capacity(REPLICAS);
    let mut endpoints = Vec::with_capacity(REPLICAS);
    for (r, part) in partitions.iter().enumerate() {
        assert!(!part.is_empty(), "replica {r} owns an empty shard");
        let mut shard = Matrix::zeros(part.len(), d);
        for (i, &g) in part.iter().enumerate() {
            shard.row_mut(i).copy_from_slice(classes.row(g as usize));
        }
        let sampler = ShardedKernelSampler::with_map(
            &shard,
            feature_map(d, seed),
            2,
            "rff-sharded",
        );
        let (server, writer) = SamplerServer::new(sampler.fork().unwrap());
        let writer = Arc::new(Mutex::new(writer));
        let batcher =
            Arc::new(MicroBatcher::spawn(server.clone(), opts_for(r)));
        let admin =
            Arc::new(Mutex::new(SharedWriterAdmin::new(writer, d)));
        let transport = TransportServer::bind_with_surface(
            sock_path(tag, r),
            Arc::clone(&batcher),
            admin,
        )
        .unwrap();
        endpoints.push(transport.endpoint().clone());
        replicas.push(Replica { server, batcher, transport: Some(transport) });
    }
    let cluster = Cluster::connect(endpoints, copts);
    cluster.seed(&partitions);
    ClusterFixture { reference, cluster, replicas }
}

fn fast_opts(_r: usize) -> BatcherOptions {
    BatcherOptions { max_batch: 16, max_wait: Duration::from_micros(50) }
}

/// Relative closeness for mass-merged probabilities: the replica trees
/// accumulate f32 partial sums over different row subsets than the
/// union reference, so bit-identity is out, but the merge itself is
/// exact math — anything past ~1e-6 relative drift is a real bug.
fn close(got: f64, want: f64) -> bool {
    (got - want).abs() <= 1e-4 * want.abs().max(1e-9)
}

// -- 1. distribution: merged draws vs the union sampler -----------------

#[test]
fn merged_draws_chi_square_consistent_with_union_sampler() {
    let (n, d) = (32, 6);
    let fx = fixture(n, d, 3000, "chi2", fast_opts, ClusterOptions::default());
    let mut router = fx.cluster.client();
    let mut rng = Rng::seeded(3001);
    let h = unit_vector(&mut rng, d);

    // 75 wave bursts of 8 sample requests, 8 draws each: 4800 draws.
    // Per-draw probabilities must match the union sampler exactly
    // (mass-weighted rescale, not an approximation); draw counts must
    // be χ²-consistent with that distribution.
    let (bursts, per_burst, m) = (75usize, 8usize, 8usize);
    let mut counts = vec![0usize; n];
    for b in 0..bursts {
        let queries: Vec<rfsoftmax::cluster::ClusterQuery> = (0..per_burst)
            .map(|j| rfsoftmax::cluster::ClusterQuery::Sample {
                h: h.clone(),
                m,
                seed: 0xC1A0 + (b * per_burst + j) as u64,
            })
            .collect();
        for res in router.query_burst(&queries, true) {
            let reply = match res.unwrap() {
                rfsoftmax::cluster::ClusterReply::Sample(reply) => reply,
                other => panic!("sample reply kind mismatch: {other:?}"),
            };
            assert_eq!(reply.draw.len(), m);
            for (&id, &q) in reply.draw.ids.iter().zip(&reply.draw.probs) {
                assert!((id as usize) < n, "non-global id {id}");
                let want = fx.reference.probability(&h, id as usize);
                assert!(
                    close(q, want),
                    "merged q {q} vs union {want} for class {id}"
                );
                counts[id as usize] += 1;
            }
        }
    }
    let trials = (bursts * per_burst * m) as f64;
    for i in 0..n {
        let q = fx.reference.probability(&h, i);
        let expect = trials * q;
        let sd = (trials * q * (1.0 - q)).sqrt().max(1.0);
        assert!(
            (counts[i] as f64 - expect).abs() <= 5.0 * sd + 3.0,
            "class {i}: merged count {} vs union expectation {expect:.1}",
            counts[i]
        );
    }

    // Point probabilities and top-k merge against the same reference.
    for class in [0u32, 11, 19, 31] {
        let (q, _) = router.probability(&h, class).unwrap();
        let want = fx.reference.probability(&h, class as usize);
        assert!(close(q, want), "probability {q} vs union {want}");
    }
    let (top, _) = router.top_k(&h, 5).unwrap();
    let want: HashMap<u32, f64> =
        fx.reference.top_k(&h, 5).into_iter().collect();
    assert_eq!(top.len(), 5);
    for (id, score) in &top {
        let w = want.get(id).unwrap_or_else(|| {
            panic!("cluster top-5 id {id} not in union top-5: {top:?}")
        });
        assert!(close(*score, *w), "top-k score {score} vs union {w}");
    }
}

// -- 2. churn convergence ------------------------------------------------

#[test]
fn churn_converges_every_replica_to_the_same_cursor() {
    let (n, d) = (48, 6);
    let fx =
        fixture(n, d, 3100, "churn", fast_opts, ClusterOptions::default());
    let mut router = fx.cluster.client();
    let mut rng = Rng::seeded(3101);

    // 30 adds in three batches (the ring spreads them over all three
    // replicas), then retire a dozen of the originals.
    let mut added: Vec<u32> = Vec::new();
    for _ in 0..3 {
        let mut emb = Matrix::zeros(10, d);
        for row in 0..10 {
            emb.row_mut(row).copy_from_slice(&unit_vector(&mut rng, d));
        }
        let (globals, _) = router.add_classes(&emb);
        assert_eq!(globals.len(), 10);
        added.extend(globals);
    }
    assert!(
        added.iter().all(|&g| g as usize >= n),
        "added ids must extend the global space, got {added:?}"
    );
    let victims: Vec<u32> = (0..12).map(|i| (i * 4) as u32).collect();
    router.retire_classes(&victims);

    // Finish with one retire that touches every replica: the entry
    // fans into one per-owner log record sharing a single sequence
    // number, so convergence means all three cursors equal it.
    let registry = fx.cluster.registry();
    let mut per_owner: Vec<Option<u32>> = vec![None; REPLICAS];
    for &g in &added {
        let owner = registry.owner_of(g);
        per_owner[owner].get_or_insert(g);
    }
    let last: Vec<u32> = per_owner.iter().flatten().copied().collect();
    assert_eq!(last.len(), REPLICAS, "30 adds left a replica unowned");
    let final_seq = router.retire_classes(&last);

    assert!(
        fx.cluster.flush(Duration::from_secs(10)),
        "replication flush wedged"
    );
    assert_eq!(fx.cluster.lag(), vec![0; REPLICAS]);
    assert_eq!(fx.cluster.dropped(), vec![0; REPLICAS]);
    assert_eq!(
        fx.cluster.cursors(),
        vec![final_seq; REPLICAS],
        "replicas converged to different epoch-sequence cursors"
    );

    // Replica-local live sets sum to the global live count.
    let live: usize = fx
        .replicas
        .iter()
        .map(|rep| rep.server.snapshot().sampler().live_classes())
        .sum();
    assert_eq!(live, n + 30 - 12 - REPLICAS);

    // Retired ids answer the typed unknown-class error; surviving
    // added ids serve real probabilities.
    let h = unit_vector(&mut rng, d);
    match router.probability(&h, victims[0]) {
        Err(ClusterError::UnknownClass(g)) => assert_eq!(g, victims[0]),
        other => panic!("retired class must be unknown, got {other:?}"),
    }
    let keep = added.iter().copied().find(|g| !last.contains(g)).unwrap();
    let (q, _) = router.probability(&h, keep).unwrap();
    assert!(q.is_finite() && q > 0.0, "added class unservable: q={q}");
}

// -- 3. failover ---------------------------------------------------------

#[test]
fn replica_death_mid_load_fails_over_without_wedging() {
    let (n, d) = (32, 6);
    let mut fx = fixture(
        n,
        d,
        3200,
        "failover",
        fast_opts,
        ClusterOptions {
            request_timeout: Duration::from_millis(800),
            hedge: false,
            virtual_nodes: VNODES,
        },
    );
    let mut router = fx.cluster.client();
    let mut rng = Rng::seeded(3201);
    let h = unit_vector(&mut rng, d);
    for i in 0..5u64 {
        router.sample(&h, 6, 0xD0A0 + i).unwrap();
    }

    // Kill replica 1: dropping the transport closes the listener and
    // every accepted connection, exactly like a process death.
    let victim = 1usize;
    fx.replicas[victim].transport = None;

    // Reads keep serving from the survivors. The first request after
    // the kill observes the loss, marks the replica down, and
    // re-routes; typed transport errors are tolerated, hangs and
    // panics are not.
    let mut served = 0usize;
    for i in 0..20u64 {
        match router.sample(&h, 6, 0xD100 + i) {
            Ok(reply) => {
                served += 1;
                for &id in &reply.draw.ids {
                    assert_ne!(
                        fx.cluster.registry().owner_of(id),
                        victim,
                        "draw came from the dead replica's shard"
                    );
                }
            }
            Err(ClusterError::Protocol(_))
            | Err(ClusterError::ReplicaLost(_)) => {}
            Err(e) => panic!("untyped failover behavior: {e}"),
        }
    }
    assert!(served >= 15, "cluster wedged after kill: {served}/20 served");
    assert!(!fx.cluster.registry().replica(victim).is_healthy());
    assert_eq!(fx.cluster.alive(), REPLICAS - 1);
    assert!(
        fx.cluster.metrics().counter("cluster.failovers").get() >= 1,
        "failover never recorded"
    );

    // Owner-exclusive lookups on the dead shard degrade loudly with
    // the typed error, never a hang.
    let dead_class = (0..n as u32)
        .find(|&g| fx.cluster.registry().owner_of(g) == victim)
        .unwrap();
    match router.probability(&h, dead_class) {
        Err(ClusterError::ReplicaDown(r)) => assert_eq!(r, victim),
        other => panic!("wanted ReplicaDown({victim}), got {other:?}"),
    }

    // Churn aimed at the dead replica is abandoned, not wedged: flush
    // terminates, the loss is counted, the cursor still advances.
    let seq = router.retire_classes(&[dead_class]);
    assert!(
        fx.cluster.flush(Duration::from_secs(10)),
        "flush wedged on a dead replica"
    );
    assert!(fx.cluster.dropped()[victim] >= 1, "abandoned entry uncounted");
    assert_eq!(fx.cluster.cursors()[victim], seq);
}

// -- 4. hedging never double-counts --------------------------------------

#[test]
fn hedged_stragglers_never_double_count_logical_requests() {
    let (n, d) = (32, 6);
    // Replica 2's batcher coalesces for a long 300ms window — a
    // built-in straggler — while the others answer in ~50µs.
    let victim = 2usize;
    let fx = fixture(
        n,
        d,
        3300,
        "hedge",
        |r| {
            if r == victim {
                BatcherOptions {
                    max_batch: 64,
                    max_wait: Duration::from_millis(300),
                }
            } else {
                fast_opts(r)
            }
        },
        ClusterOptions {
            request_timeout: Duration::from_secs(2),
            hedge: true,
            virtual_nodes: VNODES,
        },
    );
    let registry = Arc::clone(fx.cluster.registry());
    let fast: Vec<u32> =
        (0..n as u32).filter(|&g| registry.owner_of(g) != victim).collect();
    let slow =
        (0..n as u32).find(|&g| registry.owner_of(g) == victim).unwrap();
    let mut router = fx.cluster.client();
    let mut rng = Rng::seeded(3301);
    let h = unit_vector(&mut rng, d);

    // Warm the sub-wave histogram on fast-owner probabilities until
    // hedging arms with a p99-derived delay in the low milliseconds.
    // (MASS frames are answered inline by every server — the victim's
    // slow batcher never delays phase 1, only its serve sub-batch.)
    let warm = 48usize;
    for i in 0..warm {
        let (q, _) =
            router.probability(&h, fast[i % fast.len()]).unwrap();
        assert!(q.is_finite());
    }
    let metrics = fx.cluster.metrics();
    let fired_before = metrics.counter("cluster.hedges_fired").get();

    // The victim-owned probability sits in its 300ms coalesce window —
    // far past the armed hedge delay — so the router abandons the
    // straggler connection, replays the identical sub-batch on a fresh
    // one, and still returns the exact union answer.
    let (q, _) = router.probability(&h, slow).unwrap();
    assert!(
        close(q, fx.reference.probability(&h, slow as usize)),
        "hedged answer diverged from the union sampler"
    );
    assert!(
        metrics.counter("cluster.hedges_fired").get() > fired_before,
        "straggler did not trip the hedge"
    );
    assert!(
        metrics.counter("cluster.hedges_won").get() >= 1,
        "hedge replay never won"
    );

    // Reconciliation invariant: however many duplicates raced, the
    // logical request counter moved exactly once per request — while
    // the victim's own server stats prove the duplicate really hit
    // the wire (the same probability served at least twice).
    assert_eq!(
        metrics.counter("cluster.requests").get(),
        (warm + 1) as u64,
        "hedges double-counted logical requests"
    );
    let victim_probs = fx.replicas[victim].batcher.stats().probabilities;
    assert!(
        victim_probs >= 2,
        "hedge duplicate never reached the straggler: {victim_probs}"
    );
    // No replica died: hedging is a race, not a failover.
    assert_eq!(fx.cluster.alive(), REPLICAS);
    assert_eq!(metrics.counter("cluster.failovers").get(), 0);
}

// -- 5. snapshot-bootstrap rejoin -----------------------------------------

#[test]
fn killed_replica_rejoins_via_snapshot_bootstrap() {
    use rfsoftmax::admin::AdminSurface;
    use rfsoftmax::transport::TransportClient;

    let (n, d) = (36, 6);
    let seed = 3400u64;
    let mut fx = fixture(
        n,
        d,
        seed,
        "bootstrap",
        fast_opts,
        ClusterOptions {
            request_timeout: Duration::from_millis(800),
            hedge: false,
            virtual_nodes: VNODES,
        },
    );
    let mut router = fx.cluster.client();
    let mut rng = Rng::seeded(seed + 1);
    let victim = 0usize;
    let victim_endpoint =
        fx.cluster.registry().replica(victim).endpoint.clone();

    // Churn round 1 (replica alive): 9 adds, 3 retires, fully flushed —
    // this is the state the durable snapshot will capture.
    let mut emb = Matrix::zeros(9, d);
    for row in 0..9 {
        emb.row_mut(row).copy_from_slice(&unit_vector(&mut rng, d));
    }
    let (round1, _) = router.add_classes(&emb);
    router.retire_classes(&[0, 4, 8]);
    assert!(fx.cluster.flush(Duration::from_secs(10)), "round-1 flush");
    assert_eq!(fx.cluster.dropped(), vec![0; REPLICAS]);

    // Fetch the victim's durable state over the wire with a tiny chunk
    // size, so the 16 MiB frame cap machinery actually streams — the
    // snapshot must arrive in several STATE_SNAPSHOT chunks.
    let from_seq = fx.cluster.cursors()[victim];
    let mut admin_conn =
        TransportClient::connect_endpoint(&victim_endpoint).unwrap();
    let (bytes, snap_epoch) = admin_conn.fetch_snapshot(64).unwrap();
    assert!(bytes.len() > 64, "state too small to exercise chunking");
    let snap = rfsoftmax::snapshot::decode(&bytes).unwrap();
    assert_eq!(snap.epoch, snap_epoch);
    drop(admin_conn);

    // Kill the victim, then churn round 2 into the dead cluster: 18
    // adds and retires spread over every shard. The victim's share is
    // abandoned — visibly — while survivors converge.
    fx.replicas[victim].transport = None;
    let mut round2: Vec<u32> = Vec::new();
    for _ in 0..2 {
        let mut emb = Matrix::zeros(9, d);
        for row in 0..9 {
            emb.row_mut(row).copy_from_slice(&unit_vector(&mut rng, d));
        }
        let (globals, _) = router.add_classes(&emb);
        round2.extend(globals);
    }
    router.retire_classes(&[1, 5]);
    // Final retire touching every replica under one sequence number, so
    // post-bootstrap convergence means every cursor equals it.
    let registry = fx.cluster.registry();
    let mut per_owner: Vec<Option<u32>> = vec![None; REPLICAS];
    for &g in &round2 {
        per_owner[registry.owner_of(g)].get_or_insert(g);
    }
    let last: Vec<u32> = per_owner.iter().flatten().copied().collect();
    assert_eq!(last.len(), REPLICAS, "18 adds left a replica unowned");
    let final_seq = router.retire_classes(&last);

    assert!(fx.cluster.flush(Duration::from_secs(10)), "dead-replica flush");
    let lost = fx.cluster.dropped()[victim];
    assert!(lost >= 1, "victim saw none of round 2");
    assert!(
        !fx.cluster.abandoned()[victim].is_empty(),
        "abandon must record its seq ranges"
    );

    // Recover: fresh skeleton over the original shard, state replaced
    // wholesale by the snapshot through the same admin surface, rebound
    // at the same endpoint. Slot assignment is deterministic, so the
    // restored replica reproduces the dead one's local ids and the
    // registry's existing global→local bindings stay valid.
    let partitions = shard_partition(n, REPLICAS, VNODES);
    let mut srng = Rng::seeded(seed);
    let classes = Matrix::randn(&mut srng, n, d).l2_normalized_rows();
    let mut shard = Matrix::zeros(partitions[victim].len(), d);
    for (i, &g) in partitions[victim].iter().enumerate() {
        shard.row_mut(i).copy_from_slice(classes.row(g as usize));
    }
    let skeleton = ShardedKernelSampler::with_map(
        &shard,
        feature_map(d, seed),
        2,
        "rff-sharded",
    );
    let (server, writer) = SamplerServer::new(skeleton.fork().unwrap());
    let writer = Arc::new(Mutex::new(writer));
    let batcher =
        Arc::new(MicroBatcher::spawn(server.clone(), fast_opts(victim)));
    let mut surface = SharedWriterAdmin::new(Arc::clone(&writer), d);
    surface.admin_restore(snap.state.clone()).unwrap();
    let transport = TransportServer::bind_with_surface(
        sock_path("bootstrap", victim),
        Arc::clone(&batcher),
        Arc::new(Mutex::new(surface)),
    )
    .unwrap();
    fx.replicas[victim] =
        Replica { server, batcher, transport: Some(transport) };

    // Bootstrap: verified replay of exactly the abandoned tail, then
    // convergence — zero lost churn ops.
    let replayed = fx.cluster.bootstrap_replica(victim, from_seq).unwrap();
    assert_eq!(replayed, lost, "replay must cover exactly the abandoned ops");
    assert!(fx.cluster.flush(Duration::from_secs(10)), "bootstrap flush");
    assert_eq!(fx.cluster.dropped(), vec![0; REPLICAS], "churn ops lost");
    assert!(fx.cluster.abandoned()[victim].is_empty());
    assert_eq!(fx.cluster.lag(), vec![0; REPLICAS]);
    assert_eq!(
        fx.cluster.cursors(),
        vec![final_seq; REPLICAS],
        "rejoined replica did not converge to the shared cursor"
    );
    assert_eq!(fx.cluster.alive(), REPLICAS);

    // The rejoined replica serves: global live counts match the
    // never-crashed accounting, and classes from every churn era answer
    // through the router — including round-2 adds the victim only ever
    // saw through the bootstrap replay.
    let live: usize = fx
        .replicas
        .iter()
        .map(|rep| rep.server.snapshot().sampler().live_classes())
        .sum();
    assert_eq!(live, n + 9 + 18 - 3 - 2 - REPLICAS);
    let h = unit_vector(&mut rng, d);
    for g in [round1[0], round2[0]] {
        if last.contains(&g) {
            continue;
        }
        let (q, _) = router.probability(&h, g).unwrap();
        assert!(q.is_finite() && q > 0.0, "class {g} unservable: q={q}");
    }
    // Prefer a class the victim only ever saw through the bootstrap
    // replay; if the ring gave the victim exactly one round-2 add (and
    // the final retire took it), fall back to a snapshot-restored one.
    let victim_class = round2
        .iter()
        .chain(round1.iter())
        .copied()
        .find(|&g| registry.owner_of(g) == victim && !last.contains(&g))
        .expect("a live class owned by the victim");
    let (q, _) = router.probability(&h, victim_class).unwrap();
    assert!(
        q.is_finite() && q > 0.0,
        "bootstrap-replayed class unservable: q={q}"
    );
}
