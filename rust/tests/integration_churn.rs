//! Mutable-class-universe integration: churn (adds + retires) driven
//! through every layer — sampler, epoch-versioned serving, and the uds
//! wire's admin frames — checked against from-scratch rebuilds on the
//! final class set.
//!
//! * chi-square of the churned sampler's draws vs a sampler rebuilt from
//!   scratch on the surviving classes (unsharded + sharded kernel
//!   samplers, in-process and over the uds transport);
//! * a mid-growth epoch-swap test: concurrent readers never observe Σq
//!   drifting from 1 while a writer grows/shrinks the universe;
//! * wire round-trips for the ADD_CLASSES/RETIRE_CLASSES admin frames,
//!   including malformed-frame rejection and the no-admin-hook refusal;
//! * uds-vs-tcp equivalence: the same admin script driven over both
//!   socket kinds leaves byte-identical served states.

use rfsoftmax::featmap::RffMap;
use rfsoftmax::linalg::{unit_vector, Matrix, QuantizeKind};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{RffSampler, Sampler, ShardedKernelSampler};
use rfsoftmax::serving::{
    BatcherOptions, MicroBatcher, SamplerServer, SamplerWriter,
    SharedWriterAdmin,
};
use rfsoftmax::transport::{
    wire, ProtocolError, TransportClient, TransportServer,
};
use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// RFF dimensions chosen so kernel masses are positive w.h.p. (D large,
/// ν small): the two-level probability is then layout-independent and a
/// from-scratch rebuild — with a different pad/shard layout — is a valid
/// statistical reference for the churned sampler.
const NUM_FREQS: usize = 256;
const NU: f32 = 1.0;

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("rfsm-churn-{}-{tag}.sock", std::process::id()))
}

/// Apply a deterministic add/retire script; returns (all-classes matrix,
/// retired flags).
fn churn_script(
    sampler: &mut dyn Sampler,
    classes: &Matrix,
    seed: u64,
) -> (Matrix, Vec<bool>) {
    let d = classes.cols();
    let mut rng = Rng::seeded(seed);
    let mut all = classes.clone();
    let mut retired = vec![false; classes.rows()];
    for round in 0..4 {
        let mut add = Matrix::zeros(3, d);
        for r in 0..3 {
            let v = unit_vector(&mut rng, d);
            add.row_mut(r).copy_from_slice(&v);
        }
        let base = all.rows() as u32;
        let ids = sampler.add_classes(&add).unwrap();
        assert_eq!(ids, vec![base, base + 1, base + 2], "ids must be stable");
        for r in 0..3 {
            all.push_row(add.row(r));
            retired.push(false);
        }
        // Retire two live classes per round, spread over old + new ids.
        let live: Vec<u32> = (0..all.rows() as u32)
            .filter(|&i| !retired[i as usize])
            .collect();
        let victims = [
            live[(round * 7) % live.len()],
            live[(round * 13 + 5) % live.len()],
        ];
        assert_ne!(victims[0], victims[1], "script must pick distinct ids");
        sampler.retire_classes(&victims).unwrap();
        for &v in &victims {
            retired[v as usize] = true;
        }
    }
    (all, retired)
}

/// Chi-square of `counts` (indexed by live rank) against `reference`
/// probabilities over `trials` draws.
fn chi2_against(
    counts: &[usize],
    reference: &dyn Sampler,
    h: &[f32],
    trials: usize,
    tag: &str,
) {
    for (rank, &c) in counts.iter().enumerate() {
        let q = reference.probability(h, rank);
        let expect = q * trials as f64;
        let sd = (trials as f64 * q * (1.0 - q)).sqrt().max(1.0);
        assert!(
            (c as f64 - expect).abs() <= 5.0 * sd + 3.0,
            "{tag}: rank {rank}: churned count {c} vs rebuilt expectation \
             {expect:.1} (q = {q:.5})"
        );
    }
}

/// Shared body: churn `sampler`, then chi-square its draws against a
/// from-scratch rebuild (built by `rebuild` from the live class set).
fn churned_matches_rebuild(
    mut sampler: Box<dyn Sampler>,
    classes: Matrix,
    rebuild: impl Fn(&Matrix) -> Box<dyn Sampler>,
    seed: u64,
    tag: &str,
) {
    let (all, retired) = churn_script(sampler.as_mut(), &classes, seed);
    let live_ids: Vec<usize> =
        (0..all.rows()).filter(|&i| !retired[i]).collect();
    assert_eq!(sampler.live_classes(), live_ids.len(), "{tag}");
    assert_eq!(sampler.num_classes(), all.rows(), "{tag}");
    let mut live_mat = Matrix::zeros(0, all.cols());
    for &g in &live_ids {
        live_mat.push_row(all.row(g));
    }
    let reference = rebuild(&live_mat);

    let mut rng = Rng::seeded(seed + 99);
    let h = unit_vector(&mut rng, all.cols());
    // Retired slots carry exactly zero mass and Σq over all slots is 1.
    let mut total = 0.0;
    for i in 0..all.rows() {
        let q = sampler.probability(&h, i);
        if retired[i] {
            assert_eq!(q, 0.0, "{tag}: hole {i} has mass");
        }
        total += q;
    }
    assert!((total - 1.0).abs() < 1e-6, "{tag}: Σq = {total}");

    let trials = 120_000;
    let draw = sampler.sample(&h, trials, &mut rng);
    let mut rank_of = vec![usize::MAX; all.rows()];
    for (rank, &g) in live_ids.iter().enumerate() {
        rank_of[g] = rank;
    }
    let mut counts = vec![0usize; live_ids.len()];
    for &id in &draw.ids {
        assert!(!retired[id as usize], "{tag}: emitted retired id {id}");
        counts[rank_of[id as usize]] += 1;
    }
    chi2_against(&counts, reference.as_ref(), &h, trials, tag);
}

#[test]
fn unsharded_churn_chi_square_vs_scratch_rebuild() {
    let mut rng = Rng::seeded(3000);
    let classes = Matrix::randn(&mut rng, 24, 8).l2_normalized_rows();
    let sampler: Box<dyn Sampler> = Box::new(RffSampler::new(
        &classes,
        NUM_FREQS,
        NU,
        &mut Rng::seeded(3001),
    ));
    churned_matches_rebuild(
        sampler,
        classes,
        |live| {
            Box::new(RffSampler::new(
                live,
                NUM_FREQS,
                NU,
                &mut Rng::seeded(3001),
            ))
        },
        3002,
        "rff-unsharded",
    );
}

#[test]
fn sharded_churn_chi_square_vs_scratch_rebuild() {
    let mut rng = Rng::seeded(3100);
    let classes = Matrix::randn(&mut rng, 24, 8).l2_normalized_rows();
    let map = || RffMap::new(8, NUM_FREQS, NU, &mut Rng::seeded(3101));
    let sampler: Box<dyn Sampler> = Box::new(ShardedKernelSampler::with_map(
        &classes,
        map(),
        4,
        "rff-sharded",
    ));
    churned_matches_rebuild(
        sampler,
        classes,
        |live| {
            Box::new(ShardedKernelSampler::with_map(
                live,
                map(),
                4,
                "rff-sharded",
            ))
        },
        3102,
        "rff-sharded",
    );
}

#[test]
fn pre_reserved_capacity_absorbs_churn_without_tree_growth() {
    // A sampler built with `sampler.max_capacity` covering the whole
    // churn schedule must pay zero capacity-doubling copies across the
    // inserts, while the same schedule forces an unreserved twin to
    // grow — and the two must still serve the same distribution.
    let d = 8;
    let n0 = 16;
    let adds = 120usize;
    let mut rng = Rng::seeded(3200);
    let classes = Matrix::randn(&mut rng, n0, d).l2_normalized_rows();
    let map = || RffMap::new(d, 64, NU, &mut Rng::seeded(3201));
    let mut reserved = ShardedKernelSampler::with_map_opts(
        &classes,
        map(),
        4,
        "rff-sharded",
        n0 + adds,
        QuantizeKind::None,
    );
    let mut plain =
        ShardedKernelSampler::with_map(&classes, map(), 4, "rff-sharded");
    assert_eq!(reserved.growths(), 0);
    for _ in 0..adds {
        let mut add = Matrix::zeros(1, d);
        let v = unit_vector(&mut rng, d);
        add.row_mut(0).copy_from_slice(&v);
        reserved.add_classes(&add).unwrap();
        plain.add_classes(&add).unwrap();
        assert_eq!(
            reserved.growths(),
            0,
            "pre-reserved sampler paid a doubling copy mid-churn"
        );
    }
    assert!(
        plain.growths() > 0,
        "unreserved twin never grew — the reservation assert is vacuous"
    );
    let h = unit_vector(&mut rng, d);
    let mut total = 0.0;
    for i in 0..n0 + adds {
        let a = reserved.probability(&h, i);
        let b = plain.probability(&h, i);
        assert!(
            (a - b).abs() < 1e-6 * a.max(b) + 1e-9,
            "class {i}: reserved {a} vs plain {b}"
        );
        total += a;
    }
    assert!((total - 1.0).abs() < 1e-6, "Σq = {total}");
}

#[test]
fn readers_never_observe_sigma_q_drift_during_growth_swaps() {
    let n = 32;
    let d = 6;
    let mut rng = Rng::seeded(3200);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let offline = ShardedKernelSampler::with_map(
        &classes,
        RffMap::new(d, 32, 2.0, &mut Rng::seeded(3201)),
        4,
        "rff-sharded",
    );
    let (server, mut writer) = SamplerServer::new(offline.fork().unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..4)
        .map(|r| {
            let server = server.clone();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut rng = Rng::seeded(3210 + r);
                let h = unit_vector(&mut rng, d);
                let mut last_epoch = 0u64;
                let mut observed_sizes =
                    std::collections::HashSet::new();
                while !stop.load(Ordering::Relaxed) {
                    let snap = server.snapshot();
                    assert!(snap.epoch() >= last_epoch, "epoch regressed");
                    last_epoch = snap.epoch();
                    let slots = snap.sampler().num_classes();
                    observed_sizes.insert(slots);
                    // The pinned snapshot is a complete universe: Σq
                    // over every slot (holes contribute exactly 0) is 1
                    // even while the writer grows/shrinks mid-flight.
                    let total: f64 = (0..slots)
                        .map(|i| snap.sampler().probability(&h, i))
                        .sum();
                    assert!(
                        (total - 1.0).abs() < 1e-6,
                        "Σq = {total} at epoch {} ({} slots)",
                        snap.epoch(),
                        slots
                    );
                }
                observed_sizes.len()
            })
        })
        .collect();

    // Writer: grow + shrink under the readers, one epoch swap per
    // mutation batch.
    let mut wrng = Rng::seeded(3220);
    let mut live: Vec<u32> = (0..n as u32).collect();
    for cycle in 0..24 {
        if cycle % 3 == 2 && live.len() > n / 2 {
            let victim = live[(cycle * 11) % live.len()];
            writer.apply_retire_classes(vec![victim]).unwrap();
            live.retain(|&x| x != victim);
        } else {
            let mut emb = Matrix::zeros(2, d);
            for r in 0..2 {
                let v = unit_vector(&mut wrng, d);
                emb.row_mut(r).copy_from_slice(&v);
            }
            let ids = writer.apply_add_classes(emb).unwrap();
            live.extend_from_slice(&ids);
        }
        writer.publish();
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        let sizes_seen = r.join().unwrap();
        assert!(sizes_seen >= 1);
    }
    assert_eq!(server.epoch(), 24);
    let final_snap = server.snapshot();
    assert_eq!(final_snap.sampler().live_classes(), live.len());
}

#[test]
fn uds_admin_churn_chi_square_vs_scratch_rebuild() {
    let n = 24;
    let d = 8;
    let mut rng = Rng::seeded(3300);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let map = || RffMap::new(d, NUM_FREQS, NU, &mut Rng::seeded(3301));
    let offline =
        ShardedKernelSampler::with_map(&classes, map(), 4, "rff-sharded");
    let (server, writer) = SamplerServer::new(offline.fork().unwrap());
    let writer = Arc::new(Mutex::new(writer));
    let batcher = Arc::new(MicroBatcher::spawn(
        server.clone(),
        BatcherOptions::default(),
    ));
    let admin =
        Arc::new(Mutex::new(SharedWriterAdmin::new(Arc::clone(&writer), d)));
    let transport = TransportServer::bind_with_surface(
        sock_path("admin-chi2"),
        Arc::clone(&batcher),
        admin,
    )
    .unwrap();
    let mut client = TransportClient::connect(transport.path()).unwrap();

    // Drive the same churn script over the wire, mirroring it locally.
    let mut all = classes.clone();
    let mut retired = vec![false; n];
    let mut crng = Rng::seeded(3302);
    for round in 0..4u64 {
        let mut add = Matrix::zeros(3, d);
        for r in 0..3 {
            let v = unit_vector(&mut crng, d);
            add.row_mut(r).copy_from_slice(&v);
        }
        let base = all.rows() as u32;
        let (ids, epoch) = client.add_classes(&add).unwrap();
        assert_eq!(ids, vec![base, base + 1, base + 2]);
        assert_eq!(epoch, 2 * round + 1, "one swap per admin frame");
        for r in 0..3 {
            all.push_row(add.row(r));
            retired.push(false);
        }
        let live: Vec<u32> = (0..all.rows() as u32)
            .filter(|&i| !retired[i as usize])
            .collect();
        let victim = live[(round as usize * 7 + 2) % live.len()];
        let epoch = client.retire_classes(&[victim]).unwrap();
        assert_eq!(epoch, 2 * round + 2);
        retired[victim as usize] = true;
    }

    // From-scratch rebuild on the surviving set.
    let live_ids: Vec<usize> =
        (0..all.rows()).filter(|&i| !retired[i]).collect();
    let mut live_mat = Matrix::zeros(0, d);
    for &g in &live_ids {
        live_mat.push_row(all.row(g));
    }
    let reference =
        ShardedKernelSampler::with_map(&live_mat, map(), 4, "rff-sharded");

    // Chi-square the *transported* draws against the rebuild.
    let h = unit_vector(&mut crng, d);
    let m = 2000;
    let rounds = 40usize;
    let mut rank_of = vec![usize::MAX; all.rows()];
    for (rank, &g) in live_ids.iter().enumerate() {
        rank_of[g] = rank;
    }
    let mut counts = vec![0usize; live_ids.len()];
    for i in 0..rounds {
        let reply = client.sample(&h, m, 0xC0FE + i as u64).unwrap();
        assert_eq!(reply.epoch, 8, "draws must come post-churn");
        for &id in &reply.draw.ids {
            assert!(
                !retired[id as usize],
                "wire emitted retired id {id}"
            );
            counts[rank_of[id as usize]] += 1;
        }
    }
    chi2_against(&counts, &reference, &h, rounds * m, "uds-admin");
    assert_eq!(transport.stats().admin_requests, 8);

    // Admin validation errors are per-request and typed; the connection
    // (and the serving path) survive them.
    let err = client.retire_classes(&[9999]).unwrap_err();
    match &err {
        ProtocolError::Remote { code, .. } => {
            assert_eq!(*code, wire::ERR_SERVE);
            assert!(!err.closes_connection());
        }
        other => panic!("expected remote serve error, got {other:?}"),
    }
    assert_eq!(client.sample(&h, 5, 1).unwrap().draw.len(), 5);
}

/// Write raw bytes, read one response frame back.
fn send_raw_expect_error(path: &PathBuf, bytes: &[u8]) -> wire::Response {
    let mut stream = UnixStream::connect(path).unwrap();
    stream.write_all(bytes).unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let (id, resp) = wire::read_response(&mut stream)
        .expect("server must answer with a typed error frame")
        .expect("connection closed without an error frame");
    assert_eq!(id, 0, "protocol errors are connection-level (id 0)");
    assert!(
        wire::read_response(&mut stream).unwrap().is_none(),
        "connection must close after a protocol error"
    );
    resp
}

#[test]
fn malformed_admin_frames_are_rejected_and_admin_requires_a_hook() {
    let n = 16;
    let d = 6;
    let mut rng = Rng::seeded(3400);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let offline = ShardedKernelSampler::with_map(
        &classes,
        RffMap::new(d, 32, 2.0, &mut Rng::seeded(3401)),
        4,
        "rff-sharded",
    );
    // Server WITHOUT an admin hook: well-formed admin frames get a typed
    // per-request refusal, not a dead connection.
    let (server, _writer) = SamplerServer::new(offline.fork().unwrap());
    let batcher = Arc::new(MicroBatcher::spawn(
        server.clone(),
        BatcherOptions::default(),
    ));
    let transport = TransportServer::bind(
        sock_path("admin-malformed"),
        Arc::clone(&batcher),
    )
    .unwrap();
    let path = transport.path().to_path_buf();

    let mut client = TransportClient::connect(&path).unwrap();
    let one = Matrix::from_vec(1, d, vec![0.5; d]);
    let err = client.add_classes(&one).unwrap_err();
    match &err {
        ProtocolError::Remote { code, message } => {
            assert_eq!(*code, wire::ERR_SERVE);
            assert!(message.contains("admin"), "message: {message}");
            assert!(!err.closes_connection());
        }
        other => panic!("expected remote refusal, got {other:?}"),
    }
    // Connection still serves.
    let h = unit_vector(&mut rng, d);
    assert_eq!(client.sample(&h, 4, 9).unwrap().draw.len(), 4);

    // Malformed admin payload (rows×dim overruns the frame) is a
    // connection-level protocol error.
    let mut valid = Vec::new();
    wire::encode_request(
        &mut valid,
        1,
        &wire::Request::AddClasses {
            dim: d as u32,
            embeddings: vec![0.5; d],
        },
    );
    // Corrupt the row count (first payload u32) to claim 1000 rows.
    let mut corrupt = valid.clone();
    corrupt[wire::HEADER_LEN..wire::HEADER_LEN + 4]
        .copy_from_slice(&1000u32.to_le_bytes());
    let resp = send_raw_expect_error(&path, &corrupt);
    let wire::Response::Error { code, message } = resp else {
        panic!("expected error frame, got {resp:?}")
    };
    assert_eq!(code, wire::ERR_PROTOCOL);
    assert!(message.contains("malformed"), "message: {message}");
    assert_eq!(transport.stats().protocol_errors, 1);
}

/// One admin-capable serving stack (uds or tcp) over a fork of
/// `offline`, returning the pieces the equivalence test needs.
fn admin_stack(
    offline: &ShardedKernelSampler<RffMap>,
    d: usize,
    tcp: bool,
    tag: &str,
) -> (SamplerServer, Arc<MicroBatcher>, TransportServer, TransportClient) {
    let (server, writer) = SamplerServer::new(offline.fork().unwrap());
    let writer = Arc::new(Mutex::new(writer));
    let batcher = Arc::new(MicroBatcher::spawn(
        server.clone(),
        BatcherOptions::default(),
    ));
    let admin =
        Arc::new(Mutex::new(SharedWriterAdmin::new(Arc::clone(&writer), d)));
    let transport = if tcp {
        TransportServer::bind_tcp_with_surface(
            "127.0.0.1:0",
            Arc::clone(&batcher),
            admin,
        )
        .unwrap()
    } else {
        TransportServer::bind_with_surface(
            sock_path(tag),
            Arc::clone(&batcher),
            admin,
        )
        .unwrap()
    };
    let client =
        TransportClient::connect_endpoint(transport.endpoint()).unwrap();
    (server, batcher, transport, client)
}

#[test]
fn tcp_and_uds_admin_churn_leave_identical_served_states() {
    let n = 24;
    let d = 8;
    let mut rng = Rng::seeded(3500);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let offline = ShardedKernelSampler::with_map(
        &classes,
        RffMap::new(d, NUM_FREQS, NU, &mut Rng::seeded(3501)),
        4,
        "rff-sharded",
    );
    // Two forks of the same state, one behind each socket kind.
    let (uds_server, _ub, _ut, mut uds_client) =
        admin_stack(&offline, d, false, "uds-tcp-equiv");
    let (tcp_server, _tb, _tt, mut tcp_client) =
        admin_stack(&offline, d, true, "unused");

    // Drive the identical admin script through both wires: adds carry
    // deliberately UNnormalized embeddings so the equivalence also
    // covers the admin hook's ingestion-normalization contract.
    let mut crng = Rng::seeded(3502);
    let mut next_id = n as u32;
    let mut live: Vec<u32> = (0..n as u32).collect();
    for round in 0..4u64 {
        let mut add = Matrix::zeros(2, d);
        for r in 0..2 {
            let mut v = unit_vector(&mut crng, d);
            for x in &mut v {
                *x *= 3.0;
            }
            add.row_mut(r).copy_from_slice(&v);
        }
        let (ids_u, epoch_u) = uds_client.add_classes(&add).unwrap();
        let (ids_t, epoch_t) = tcp_client.add_classes(&add).unwrap();
        assert_eq!(ids_u, ids_t, "round {round}: assigned ids diverged");
        assert_eq!(epoch_u, epoch_t);
        assert_eq!(ids_u, vec![next_id, next_id + 1]);
        live.extend_from_slice(&ids_u);
        next_id += 2;
        let victim = live[(round as usize * 5 + 1) % live.len()];
        assert_eq!(
            uds_client.retire_classes(&[victim]).unwrap(),
            tcp_client.retire_classes(&[victim]).unwrap(),
            "round {round}: retire epochs diverged"
        );
        live.retain(|&i| i != victim);
    }

    // The served states must now be byte-identical: exact probabilities,
    // identical top-k rankings, identical draws for equal seeds.
    let usnap = uds_server.snapshot();
    let tsnap = tcp_server.snapshot();
    assert_eq!(usnap.epoch(), tsnap.epoch());
    assert_eq!(
        usnap.sampler().live_classes(),
        tsnap.sampler().live_classes()
    );
    assert_eq!(usnap.sampler().live_classes(), live.len());
    let mut prng = Rng::seeded(3503);
    for probe in 0..6u64 {
        let h = unit_vector(&mut prng, d);
        for class in 0..(n + 8) {
            let (qu, _) = uds_client.probability(&h, class).unwrap();
            let (qt, _) = tcp_client.probability(&h, class).unwrap();
            assert_eq!(qu, qt, "probe {probe}: q({class}) diverged");
        }
        let (tu, _) = uds_client.top_k(&h, 5).unwrap();
        let (tt, _) = tcp_client.top_k(&h, 5).unwrap();
        assert_eq!(tu, tt, "probe {probe}: top-k diverged");
        let su = uds_client.sample(&h, 6, 0xC0FE + probe).unwrap();
        let st = tcp_client.sample(&h, 6, 0xC0FE + probe).unwrap();
        assert_eq!(su.draw, st.draw, "probe {probe}: draws diverged");
    }
}
