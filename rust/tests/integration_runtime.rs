//! Integration tests over the runtime backend seam.
//!
//! The default build exercises the **native** backend: no artifacts,
//! no `pjrt` feature — `Runtime::native()` plus a config is a complete
//! training stack. The PJRT artifact tests (HLO executables produced by
//! `make artifacts`) live in the feature-gated module at the bottom and
//! only compile with `--features pjrt`; there they still skip politely
//! when the artifacts are missing.

use rfsoftmax::config::Config;
use rfsoftmax::coordinator::TrainerBuilder;
use rfsoftmax::runtime::Runtime;

#[test]
fn native_backend_needs_no_artifacts() {
    let rt = Runtime::native();
    assert!(rt.is_native());
    assert!(rt.artifact_dir().as_os_str().is_empty());
    assert!(!rt.has("quickstart_train_sampled"));
    // A trainer must build straight from the config — no manifest.
    let mut cfg = Config::default();
    for (k, v) in [
        ("model.num_classes", "200"),
        ("model.embed_dim", "16"),
        ("model.hidden_dim", "16"),
        ("model.seq_len", "4"),
        ("sampler.kind", "uniform"),
        ("sampler.num_negatives", "10"),
        ("train.batch_size", "8"),
        ("train.steps", "2"),
        ("train.eval_every", "2"),
        ("train.eval_batches", "2"),
        ("data.train_size", "2000"),
        ("data.valid_size", "500"),
    ] {
        cfg.set(k, v).unwrap();
    }
    let mut t = TrainerBuilder::new(&rt, "seam", cfg).build().unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps_run, 2);
}

#[test]
fn native_eval_loss_close_to_log_n_at_init() {
    // With near-random parameters the full-softmax eval loss should sit
    // near ln(n) (uniform-ish predictions) — the same sanity anchor the
    // pjrt eval artifact is held to.
    let rt = Runtime::native();
    let mut cfg = Config::default();
    for (k, v) in [
        ("model.num_classes", "1000"),
        ("model.embed_dim", "32"),
        ("model.hidden_dim", "32"),
        ("model.seq_len", "8"),
        ("sampler.kind", "uniform"),
        ("sampler.num_negatives", "20"),
        ("train.batch_size", "16"),
        ("train.steps", "1"),
        ("train.eval_every", "1"),
        ("train.eval_batches", "4"),
        ("train.lr", "0.01"),
        ("data.train_size", "5000"),
        ("data.valid_size", "1000"),
    ] {
        cfg.set(k, v).unwrap();
    }
    let mut t = TrainerBuilder::new(&rt, "seam", cfg).build().unwrap();
    let report = t.run().unwrap();
    let loss = report.history.first().unwrap().eval_loss;
    let logn = (1000f64).ln();
    // With τ ≈ 11 the random logits have std ≈ 2, inflating the
    // logsumexp by ~σ²/2 above ln(n); accept [ln n − 1, ln n + 4].
    assert!(
        loss > logn - 1.0 && loss < logn + 4.0,
        "init eval loss {loss} implausible vs ln(n) = {logn}"
    );
}

/// PJRT artifact tests: only meaningful in a `--features pjrt` build,
/// and within one only when `make artifacts` has produced at least the
/// `quickstart` and `rff_map` configs (they skip with a message
/// otherwise so `cargo test` stays usable before the first build).
#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use rfsoftmax::linalg::Matrix;
    use rfsoftmax::rng::Rng;
    use rfsoftmax::runtime::{HostTensor, Runtime};

    fn runtime_or_skip() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        match Runtime::load(&dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("SKIP (no artifacts): {e}");
                None
            }
        }
    }

    #[test]
    fn rff_map_artifact_matches_rust_featmap() {
        let Some(rt) = runtime_or_skip() else { return };
        if !rt.has("rff_map") {
            eprintln!("SKIP: rff_map artifact not built");
            return;
        }
        let exe = rt.get("rff_map").expect("compile rff_map");
        let rows = exe.meta.inputs[0].shape[0];
        let d = exe.meta.inputs[0].shape[1];
        let num_freqs = exe.meta.inputs[1].shape[0];

        // Build a frequency matrix w with ν = 1 and the same inputs on
        // both sides. The Rust RffMap draws its own w, so instead we
        // compare against the *reference math*:
        // φ = [cos(uWᵀ)|sin(uWᵀ)]/√D.
        let mut rng = Rng::seeded(2024);
        let u = Matrix::randn(&mut rng, rows, d);
        let w = Matrix::randn(&mut rng, num_freqs, d);
        let outs = exe
            .run(&[
                HostTensor::f32(&[rows, d], u.data().to_vec()),
                HostTensor::f32(&[num_freqs, d], w.data().to_vec()),
            ])
            .expect("execute rff_map");
        let phi = outs[0].as_f32();
        assert_eq!(outs[0].shape(), &[rows, num_freqs * 2]);

        let inv_sqrt = 1.0 / (num_freqs as f32).sqrt();
        let mut max_err = 0.0f32;
        for i in 0..rows {
            for j in 0..num_freqs {
                let proj = rfsoftmax::linalg::dot(u.row(i), w.row(j));
                let c = proj.cos() * inv_sqrt;
                let s = proj.sin() * inv_sqrt;
                max_err =
                    max_err.max((phi[i * 2 * num_freqs + j] - c).abs());
                max_err = max_err
                    .max((phi[i * 2 * num_freqs + num_freqs + j] - s).abs());
            }
        }
        assert!(max_err < 1e-4, "pallas vs reference max err {max_err}");
    }

    #[test]
    fn sampled_loss_artifact_matches_rust_oracle() {
        let Some(rt) = runtime_or_skip() else { return };
        if !rt.has("quickstart_train_sampled") {
            eprintln!("SKIP: quickstart artifacts not built");
            return;
        }
        let exe = rt.get("quickstart_train_sampled").expect("compile");
        let meta = &exe.meta;
        let b = meta.meta_usize("batch").unwrap();
        let l = meta.meta_usize("seq_len").unwrap();
        let d = meta.meta_usize("d").unwrap();
        let h = meta.meta_usize("hidden").unwrap();
        let m = meta.meta_usize("m").unwrap();
        let tau = meta.meta_f64("tau").unwrap() as f32;

        let mut rng = Rng::seeded(7);
        let ctx = Matrix::randn_scaled(&mut rng, b * l, d, 0.1);
        let wx = Matrix::randn_scaled(&mut rng, d, 4 * h, 0.05);
        let wh = Matrix::randn_scaled(&mut rng, h, 4 * h, 0.05);
        let bias = vec![0.0f32; 4 * h];
        let proj = Matrix::randn_scaled(&mut rng, h, d, 0.1);
        let tgt = Matrix::randn(&mut rng, b, d).l2_normalized_rows();
        let neg = Matrix::randn(&mut rng, m, d).l2_normalized_rows();
        let adjust: Vec<f32> =
            (0..m).map(|_| rng.gaussian_f32() * 0.1).collect();
        let mask = vec![1.0f32; b * m];

        // 1. Run the full train-step artifact.
        let outs = exe
            .run(&[
                HostTensor::f32(&[b, l, d], ctx.data().to_vec()),
                HostTensor::f32(&[d, 4 * h], wx.data().to_vec()),
                HostTensor::f32(&[h, 4 * h], wh.data().to_vec()),
                HostTensor::f32(&[4 * h], bias.clone()),
                HostTensor::f32(&[h, d], proj.data().to_vec()),
                HostTensor::f32(&[b, d], tgt.data().to_vec()),
                HostTensor::f32(&[m, d], neg.data().to_vec()),
                HostTensor::f32(&[m], adjust.clone()),
                HostTensor::f32(&[b, m], mask),
            ])
            .expect("execute train_sampled");
        let loss = outs[0].scalar() as f64;
        assert!(loss.is_finite() && loss > 0.0, "loss = {loss}");
        // Gradient arity: loss + 7 gradients.
        assert_eq!(outs.len(), 8);

        // 2. Cross-check the loss against the Rust oracle via the
        //    encoder artifact (h from PJRT, loss math in pure Rust).
        let enc = rt.get("quickstart_encode").expect("compile encode");
        let enc_out = enc
            .run(&[
                HostTensor::f32(&[b, l, d], ctx.data().to_vec()),
                HostTensor::f32(&[d, 4 * h], wx.data().to_vec()),
                HostTensor::f32(&[h, 4 * h], wh.data().to_vec()),
                HostTensor::f32(&[4 * h], bias),
                HostTensor::f32(&[h, d], proj.data().to_vec()),
            ])
            .expect("execute encode");
        let hmat = enc_out[0].as_f32();
        let mut acc = 0.0f64;
        for i in 0..b {
            let hi = &hmat[i * d..(i + 1) * d];
            let o_t = (tau * rfsoftmax::linalg::dot(hi, tgt.row(i))) as f64;
            let negs: Vec<f64> = (0..m)
                .map(|j| {
                    (tau * rfsoftmax::linalg::dot(hi, neg.row(j))) as f64
                })
                .collect();
            // q such that log(m·q) = adjust  ⇔  q = exp(adjust)/m.
            let q: Vec<f64> = adjust
                .iter()
                .map(|&a| (a as f64).exp() / m as f64)
                .collect();
            let s = rfsoftmax::softmax::sampled_softmax_loss(o_t, &negs, &q);
            acc += s.loss;
        }
        let oracle = acc / b as f64;
        assert!(
            (loss - oracle).abs() < 1e-3 * oracle.abs().max(1.0),
            "artifact loss {loss} vs rust oracle {oracle}"
        );
    }

    #[test]
    fn manifest_lists_expected_quickstart_entries() {
        let Some(rt) = runtime_or_skip() else { return };
        for entry in [
            "quickstart_encode",
            "quickstart_train_sampled",
            "quickstart_train_full",
            "quickstart_eval",
        ] {
            assert!(rt.has(entry), "missing manifest entry {entry}");
        }
    }

    #[test]
    fn eval_artifact_loss_close_to_log_n_at_init() {
        // With random h and random class embeddings, the full softmax
        // loss should be near ln(n) (uniform-ish), a sanity anchor for
        // perplexity.
        let Some(rt) = runtime_or_skip() else { return };
        if !rt.has("quickstart_eval") {
            return;
        }
        let exe = rt.get("quickstart_eval").unwrap();
        let meta = &exe.meta;
        let (b, l, d, h, n) = (
            meta.meta_usize("batch").unwrap(),
            meta.meta_usize("seq_len").unwrap(),
            meta.meta_usize("d").unwrap(),
            meta.meta_usize("hidden").unwrap(),
            meta.meta_usize("n").unwrap(),
        );
        let mut rng = Rng::seeded(8);
        let outs = exe
            .run(&[
                HostTensor::f32(
                    &[b, l, d],
                    Matrix::randn_scaled(&mut rng, b * l, d, 0.1).into_vec(),
                ),
                HostTensor::f32(
                    &[d, 4 * h],
                    Matrix::randn_scaled(&mut rng, d, 4 * h, 0.05)
                        .into_vec(),
                ),
                HostTensor::f32(
                    &[h, 4 * h],
                    Matrix::randn_scaled(&mut rng, h, 4 * h, 0.05)
                        .into_vec(),
                ),
                HostTensor::f32(&[4 * h], vec![0.0; 4 * h]),
                HostTensor::f32(
                    &[h, d],
                    Matrix::randn_scaled(&mut rng, h, d, 0.1).into_vec(),
                ),
                HostTensor::f32(
                    &[n, d],
                    Matrix::randn_scaled(&mut rng, n, d, 0.1).into_vec(),
                ),
                HostTensor::i32(&[b], (0..b as i32).collect()),
            ])
            .expect("execute eval");
        let loss = outs[0].scalar() as f64;
        let logn = (n as f64).ln();
        // With τ ≈ 11 the random logits have std ≈ τ/√d ≈ 2, inflating
        // the logsumexp by ~σ²/2 above ln(n); accept [ln n−1, ln n+4].
        assert!(
            loss > logn - 1.0 && loss < logn + 4.0,
            "init loss {loss} implausible vs ln(n) = {logn}"
        );
    }
}
