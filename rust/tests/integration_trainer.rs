//! End-to-end trainer smoke tests over the quickstart artifacts:
//! every sampler kind must run steps, reduce the training loss, and keep
//! the coordinator's bookkeeping consistent.

use rfsoftmax::config::Config;
use rfsoftmax::coordinator::TrainerBuilder;
use rfsoftmax::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load(Runtime::default_dir()) {
        Ok(rt) if rt.has("quickstart_train_sampled") => Some(rt),
        Ok(_) | Err(_) => {
            eprintln!("SKIP: quickstart artifacts not built");
            None
        }
    }
}

fn quickstart_config(sampler: &str, steps: usize) -> Config {
    let mut cfg = Config::default();
    for (k, v) in [
        ("sampler.kind", sampler),
        ("sampler.num_negatives", "20"),
        ("sampler.dim", "64"),
        ("sampler.nu", "4.0"),
        ("train.steps", &steps.to_string()),
        ("train.eval_every", &steps.to_string()),
        ("train.eval_batches", "4"),
        ("train.lr", "0.5"),
        ("train.optimizer", "adagrad"),
        ("data.train_size", "20000"),
        ("data.valid_size", "2000"),
        // quickstart artifact shape: n=1000.
        ("model.num_classes", "1000"),
    ] {
        cfg.set(k, v).unwrap();
    }
    cfg
}

#[test]
fn rff_trainer_reduces_loss() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut cfg = quickstart_config("rff", 150);
    cfg.set("train.eval_every", "30").unwrap();
    let mut t = TrainerBuilder::new(&rt, "quickstart", cfg).build().unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps_run, 150);
    assert_eq!(report.sampler, "rff");
    let first = report.history.first().unwrap();
    let last = report.history.last().unwrap();
    // τ ≈ 11 inflates the random-init loss above ln(n) ≈ 6.9; training
    // must drive a clear monotone-ish improvement within 150 steps.
    assert!(
        last.eval_loss < first.eval_loss - 0.5,
        "no learning: eval {} → {}",
        first.eval_loss,
        last.eval_loss
    );
    assert!(last.metric.is_finite() && last.metric > 1.0);
}

#[test]
fn all_sampler_kinds_run() {
    let Some(rt) = runtime_or_skip() else { return };
    for kind in ["uniform", "loguniform", "unigram", "exact", "quadratic", "gumbel", "full"] {
        let cfg = quickstart_config(kind, 8);
        let mut t = TrainerBuilder::new(&rt, "quickstart", cfg)
            .build()
            .unwrap_or_else(|e| panic!("{kind}: {e}"));
        let report = t.run().unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert_eq!(report.steps_run, 8, "{kind}");
        assert!(
            report.history.last().unwrap().eval_loss.is_finite(),
            "{kind}: non-finite eval loss"
        );
    }
}

#[test]
fn stale_sampling_mode_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let cfg = quickstart_config("rff", 10);
    let mut t = TrainerBuilder::new(&rt, "quickstart", cfg)
        .stale_sampling(true)
        .build()
        .unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps_run, 10);
}

#[test]
fn wrong_m_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let mut cfg = quickstart_config("rff", 5);
    cfg.set("sampler.num_negatives", "33").unwrap();
    let err = match TrainerBuilder::new(&rt, "quickstart", cfg).build() {
        Ok(_) => panic!("m mismatch must fail"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("m=33"), "unhelpful error: {err}");
}

#[test]
fn checkpointing_round_trips() {
    let Some(rt) = runtime_or_skip() else { return };
    let dir = std::env::temp_dir().join("rfsm_trainer_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = quickstart_config("uniform", 5);
    cfg.train.checkpoint_dir = Some(dir.to_str().unwrap().to_string());
    let mut t = TrainerBuilder::new(&rt, "quickstart", cfg).build().unwrap();
    t.run().unwrap();
    let ckpt = dir.join("quickstart_uniform.ckpt");
    assert!(ckpt.exists(), "missing checkpoint {}", ckpt.display());
    let store = rfsoftmax::model::ParamStore::load(&ckpt).unwrap();
    assert!(store.by_name("cls").is_some());
    assert_eq!(store.by_name("cls").unwrap().rows(), 1000);
}
