//! End-to-end trainer tests on the default **native** backend: no
//! compiled artifacts, no `pjrt` feature — `Runtime::native()` plus a
//! [`Config`] is everything the fused train step needs. Every sampler
//! kind must run steps, reduce the training loss, keep the scratch
//! steady-state allocation-free, and round-trip checkpoints.

use rfsoftmax::config::Config;
use rfsoftmax::coordinator::TrainerBuilder;
use rfsoftmax::runtime::Runtime;

/// Small-but-real LM shapes: big enough that the LSTM + sampled loss
/// exercise the tiled kernels, small enough for sub-second steps.
fn lm_config(sampler: &str, steps: usize) -> Config {
    let mut cfg = Config::default();
    for (k, v) in [
        ("model.kind", "lm"),
        ("model.num_classes", "1000"),
        ("model.embed_dim", "32"),
        ("model.hidden_dim", "32"),
        ("model.seq_len", "8"),
        ("sampler.kind", sampler),
        ("sampler.num_negatives", "20"),
        ("sampler.dim", "64"),
        ("sampler.nu", "4.0"),
        ("train.batch_size", "16"),
        ("train.steps", &steps.to_string()),
        ("train.eval_every", &steps.to_string()),
        ("train.eval_batches", "4"),
        ("train.lr", "0.5"),
        ("train.optimizer", "adagrad"),
        ("data.train_size", "20000"),
        ("data.valid_size", "2000"),
    ] {
        cfg.set(k, v).unwrap();
    }
    cfg
}

#[test]
fn rff_trainer_reduces_loss() {
    let rt = Runtime::native();
    let mut cfg = lm_config("rff", 150);
    cfg.set("train.eval_every", "30").unwrap();
    let mut t = TrainerBuilder::new(&rt, "synthlm", cfg).build().unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps_run, 150);
    assert_eq!(report.sampler, "rff");
    let first = report.history.first().unwrap();
    let last = report.history.last().unwrap();
    // τ ≈ 11 inflates the random-init loss above ln(n) ≈ 6.9; training
    // must drive a clear monotone-ish improvement within 150 steps.
    assert!(
        last.eval_loss < first.eval_loss - 0.5,
        "no learning: eval {} → {}",
        first.eval_loss,
        last.eval_loss
    );
    assert!(last.metric.is_finite() && last.metric > 1.0);
}

#[test]
fn all_sampler_kinds_run() {
    let rt = Runtime::native();
    for kind in [
        "uniform",
        "loguniform",
        "unigram",
        "exact",
        "quadratic",
        "gumbel",
        "rff",
        "full",
    ] {
        let cfg = lm_config(kind, 8);
        let mut t = TrainerBuilder::new(&rt, "synthlm", cfg)
            .build()
            .unwrap_or_else(|e| panic!("{kind}: {e}"));
        let report = t.run().unwrap_or_else(|e| panic!("{kind}: {e}"));
        assert_eq!(report.steps_run, 8, "{kind}");
        assert!(
            report.history.last().unwrap().eval_loss.is_finite(),
            "{kind}: non-finite eval loss"
        );
    }
}

#[test]
fn stale_sampling_mode_runs() {
    let rt = Runtime::native();
    let cfg = lm_config("rff", 10);
    let mut t = TrainerBuilder::new(&rt, "synthlm", cfg)
        .stale_sampling(true)
        .build()
        .unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps_run, 10);
}

/// The fused path's scratch must reach steady state: after the first
/// step + first eval have sized every buffer, further steps may not
/// reallocate. 30 extra steps with per-step allocations would show up
/// as ≥30 `scratch_growths`; a healthy steady state adds (at most) a
/// couple of late `upd_buf` high-water marks.
#[test]
fn scratch_reaches_steady_state() {
    let rt = Runtime::native();
    let growths_after = |steps: usize| -> u64 {
        let mut cfg = lm_config("rff", steps);
        cfg.set("train.eval_every", "5").unwrap();
        let mut t =
            TrainerBuilder::new(&rt, "synthlm", cfg).build().unwrap();
        t.run().unwrap();
        t.metrics().counter("scratch_growths")
    };
    let warm = growths_after(10);
    let long = growths_after(40);
    assert!(warm > 0, "growth counter should see the first-step sizing");
    assert!(
        long <= warm + 5,
        "scratch grows with step count: {warm} growths at 10 steps, \
         {long} at 40 — the fused path is allocating per step"
    );
}

#[test]
fn xc_trainer_runs_on_native() {
    let rt = Runtime::native();
    let mut cfg = Config::default();
    for (k, v) in [
        ("model.kind", "extreme"),
        ("model.num_classes", "500"),
        ("model.embed_dim", "32"),
        ("model.feature_dim", "2000"),
        ("model.nnz", "8"),
        ("sampler.kind", "rff"),
        ("sampler.num_negatives", "20"),
        ("sampler.dim", "64"),
        ("train.batch_size", "16"),
        ("train.steps", "10"),
        ("train.eval_every", "10"),
        ("train.eval_batches", "4"),
        ("data.train_size", "2000"),
        ("data.valid_size", "400"),
    ] {
        cfg.set(k, v).unwrap();
    }
    let mut t = TrainerBuilder::new(&rt, "synthxc", cfg).build().unwrap();
    let report = t.run().unwrap();
    assert_eq!(report.steps_run, 10);
    let p1 = report.history.last().unwrap().metric;
    assert!((0.0..=1.0).contains(&p1), "precision@1 out of range: {p1}");
}

#[test]
fn unnormalized_requires_full_softmax() {
    let rt = Runtime::native();
    let cfg = lm_config("rff", 5);
    let err = match TrainerBuilder::new(&rt, "synthlm", cfg)
        .unnormalized(true)
        .build()
    {
        Ok(_) => panic!("unnormalized + sampled must fail"),
        Err(e) => format!("{e:#}"),
    };
    assert!(err.contains("FULL"), "unhelpful error: {err}");
}

#[test]
fn checkpointing_round_trips() {
    let rt = Runtime::native();
    let dir = std::env::temp_dir().join("rfsm_trainer_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = lm_config("uniform", 5);
    cfg.train.checkpoint_dir = Some(dir.to_str().unwrap().to_string());
    let mut t = TrainerBuilder::new(&rt, "synthlm", cfg).build().unwrap();
    t.run().unwrap();
    let ckpt = dir.join("synthlm_uniform.ckpt");
    assert!(ckpt.exists(), "missing checkpoint {}", ckpt.display());
    let store = rfsoftmax::model::ParamStore::load(&ckpt).unwrap();
    assert!(store.by_name("cls").is_some());
    assert_eq!(store.by_name("cls").unwrap().rows(), 1000);
}
