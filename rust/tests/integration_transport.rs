//! Cross-process-style loopback tests for the L4 serving transport: a
//! real `TransportServer` on a unix socket or a loopback TCP listener,
//! real `TransportClient` connections, and the shared micro-batcher in
//! between. Covers round-trips for all query kinds (admin frames
//! included) on both transports, client-vs-inproc and uds-vs-tcp seed
//! determinism (identical draws for identical seeds across the process
//! boundary and across socket kinds), a chi-square of transported
//! samples against the offline sampler, concurrent-client coalescing,
//! wire v3 batched wave pipelining (header amortization + whole-wave
//! overload shedding), malformed-frame hardening, and the read-only
//! `STATS` telemetry scrape (per-stage counts reconciling with request
//! totals on both socket kinds; v2-stamped scrape refused exactly like
//! any unknown kind).

use rfsoftmax::featmap::RffMap;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{Sampler, ShardedKernelSampler};
use rfsoftmax::serving::{BatcherOptions, MicroBatcher, SamplerServer};
use rfsoftmax::transport::{
    wire, ProtocolError, Request, Response, TransportClient, TransportServer,
};
use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn sharded_rff(
    n: usize,
    d: usize,
    seed: u64,
) -> ShardedKernelSampler<RffMap> {
    let mut rng = Rng::seeded(seed);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let map = RffMap::new(d, 32, 2.0, &mut Rng::seeded(seed + 1));
    ShardedKernelSampler::with_map(&classes, map, 4, "rff-sharded")
}

fn sock_path(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("rfsm-test-{}-{tag}.sock", std::process::id()))
}

/// Server + batcher + offline reference over the same sampler state.
fn serve_stack(
    n: usize,
    d: usize,
    seed: u64,
    opts: BatcherOptions,
    tag: &str,
) -> (ShardedKernelSampler<RffMap>, Arc<MicroBatcher>, TransportServer) {
    let offline = sharded_rff(n, d, seed);
    let (server, _writer) = SamplerServer::new(offline.fork().unwrap());
    let batcher = Arc::new(MicroBatcher::spawn(server, opts));
    let transport =
        TransportServer::bind(sock_path(tag), Arc::clone(&batcher)).unwrap();
    (offline, batcher, transport)
}

#[test]
fn loopback_round_trip_all_three_query_kinds() {
    let n = 48;
    let d = 6;
    let (offline, _batcher, transport) =
        serve_stack(n, d, 2000, BatcherOptions::default(), "roundtrip");
    let mut client = TransportClient::connect(transport.path()).unwrap();
    let mut rng = Rng::seeded(2001);
    for probe in 0..4 {
        let h = unit_vector(&mut rng, d);

        let reply = client.sample(&h, 9, 7000 + probe).unwrap();
        assert_eq!(reply.draw.len(), 9);
        assert_eq!(reply.epoch, 0);
        for (&id, &q) in reply.draw.ids.iter().zip(&reply.draw.probs) {
            assert!((id as usize) < n);
            let want = offline.probability(&h, id as usize);
            assert!(
                (q - want).abs() < 1e-12 * want.max(1e-12),
                "transported q {q} vs offline {want}"
            );
        }

        let (q, epoch) = client.probability(&h, 11).unwrap();
        assert_eq!(epoch, 0);
        assert!((q - offline.probability(&h, 11)).abs() < 1e-15);

        let (top, epoch) = client.top_k(&h, 5).unwrap();
        assert_eq!(epoch, 0);
        assert_eq!(top, offline.top_k(&h, 5));
    }
    let stats = transport.stats();
    assert_eq!(stats.connections, 1);
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.protocol_errors, 0);
}

#[test]
fn wire_draws_are_byte_identical_to_inproc_for_equal_seeds() {
    let n = 64;
    let d = 8;
    let (offline, batcher, transport) =
        serve_stack(n, d, 2100, BatcherOptions::default(), "determinism");
    let mut client = TransportClient::connect(transport.path()).unwrap();
    let mut rng = Rng::seeded(2101);
    for i in 0..12u64 {
        let h = unit_vector(&mut rng, d);
        let wired = client.sample(&h, 7, 0xABC0 + i).unwrap();
        let local = batcher.sample(&h, 7, 0xABC0 + i);
        assert_eq!(wired.epoch, local.epoch);
        assert_eq!(
            wired.draw, local.draw,
            "seed {i}: wire and inproc draws diverged"
        );
        // The deterministic kinds agree too.
        let (wq, _) = client.probability(&h, (i as usize) % n).unwrap();
        let (lq, _) = batcher.probability(&h, (i as usize) % n);
        assert_eq!(wq, lq);
        let (wt, _) = client.top_k(&h, 6).unwrap();
        let (lt, _) = batcher.top_k(&h, 6);
        assert_eq!(wt, lt);
        // And both match the offline sampler exactly.
        assert_eq!(wt, offline.top_k(&h, 6));
    }
}

#[test]
fn transported_samples_match_offline_distribution_chi_square() {
    let n = 32;
    let d = 6;
    let (offline, _batcher, transport) =
        serve_stack(n, d, 2200, BatcherOptions::default(), "chi2");
    let mut client = TransportClient::connect(transport.path()).unwrap();
    let mut rng = Rng::seeded(2201);
    let h = unit_vector(&mut rng, d);
    let m = 8;
    let rounds = 1200usize;
    let mut counts = vec![0usize; n];
    for i in 0..rounds {
        let reply = client.sample(&h, m, 0x517A + i as u64).unwrap();
        for &id in &reply.draw.ids {
            counts[id as usize] += 1;
        }
    }
    let trials = (rounds * m) as f64;
    for i in 0..n {
        let q = offline.probability(&h, i);
        let expect = q * trials;
        let sd = (trials * q * (1.0 - q)).sqrt().max(1.0);
        assert!(
            (counts[i] as f64 - expect).abs() <= 5.0 * sd + 3.0,
            "class {i}: transported count {} vs offline expectation \
             {expect:.1} (q = {q:.5})",
            counts[i]
        );
    }
}

#[test]
fn concurrent_pipelined_clients_coalesce_into_shared_batches() {
    let n = 64;
    let d = 8;
    let (_offline, batcher, transport) = serve_stack(
        n,
        d,
        2300,
        BatcherOptions { max_batch: 32, max_wait: Duration::from_millis(1) },
        "coalesce",
    );
    let clients = 4usize;
    let waves = 15usize;
    let burst = 16usize;
    let path = transport.path().to_path_buf();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let path = path.clone();
            std::thread::spawn(move || {
                let mut client = TransportClient::connect(&path).unwrap();
                let mut rng = Rng::seeded(2301 + c as u64);
                for w in 0..waves {
                    // A pipelined burst keeps `burst` requests in flight
                    // on this one connection — the server must coalesce
                    // them (and other clients') into shared waves.
                    let reqs: Vec<Request> = (0..burst)
                        .map(|j| {
                            let h = unit_vector(&mut rng, d);
                            match j % 3 {
                                0 => Request::Sample {
                                    h,
                                    m: 5,
                                    seed: (c * 10_000 + w * 100 + j) as u64,
                                },
                                1 => Request::Probability {
                                    h,
                                    class: (j % n) as u32,
                                },
                                _ => Request::TopK { h, k: 4 },
                            }
                        })
                        .collect();
                    let resps = client.pipeline(&reqs).unwrap();
                    assert_eq!(resps.len(), burst);
                    for (req, resp) in reqs.iter().zip(&resps) {
                        match (req, resp) {
                            (
                                Request::Sample { .. },
                                Response::Sample { ids, probs, .. },
                            ) => {
                                assert_eq!(ids.len(), 5);
                                assert_eq!(probs.len(), 5);
                            }
                            (
                                Request::Probability { .. },
                                Response::Probability { q, .. },
                            ) => assert!(q.is_finite()),
                            (
                                Request::TopK { .. },
                                Response::TopK { items, .. },
                            ) => assert_eq!(items.len(), 4),
                            other => panic!("kind mismatch: {other:?}"),
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, (clients * waves * burst) as u64);
    let mean_batch = stats.requests as f64 / stats.batches.max(1) as f64;
    assert!(
        mean_batch > 1.0,
        "no coalescing under pipelined load: {} requests in \
         {} batches (mean {mean_batch:.2})",
        stats.requests,
        stats.batches,
    );
    assert!(
        stats.samples > 0 && stats.probabilities > 0 && stats.top_ks > 0,
        "mix did not coalesce"
    );
}

/// Write raw bytes, read one response frame back, then confirm EOF.
fn send_raw_expect_error(path: &PathBuf, bytes: &[u8]) -> Response {
    let mut stream = UnixStream::connect(path).unwrap();
    stream.write_all(bytes).unwrap();
    stream.flush().unwrap();
    // Half-close the write side so a server waiting for more payload
    // bytes sees the truncation immediately.
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let (id, resp) = wire::read_response(&mut stream)
        .expect("server must answer with a typed error frame")
        .expect("connection closed without an error frame");
    assert_eq!(id, 0, "protocol errors are connection-level (id 0)");
    // After the error frame the server closes the connection.
    assert!(
        wire::read_response(&mut stream).unwrap().is_none(),
        "connection must close after a protocol error"
    );
    resp
}

#[test]
fn malformed_frames_get_typed_errors_and_never_poison_the_batcher() {
    let n = 32;
    let d = 6;
    let (_offline, batcher, transport) =
        serve_stack(n, d, 2400, BatcherOptions::default(), "malformed");
    let path = transport.path().to_path_buf();

    // A valid frame to mutate.
    let mut valid = Vec::new();
    wire::encode_request(
        &mut valid,
        1,
        &Request::TopK { h: vec![0.5; d], k: 3 },
    );

    // 1. Truncated: header promises payload the peer never sends.
    let resp = send_raw_expect_error(&path, &valid[..valid.len() - 4]);
    let Response::Error { code, message } = resp else {
        panic!("expected error frame, got {resp:?}")
    };
    assert_eq!(code, wire::ERR_PROTOCOL);
    assert!(message.contains("truncated"), "message: {message}");

    // 2. Oversized: length prefix beyond MAX_PAYLOAD.
    let mut oversized = valid.clone();
    oversized[12..16]
        .copy_from_slice(&(wire::MAX_PAYLOAD as u32 + 1).to_le_bytes());
    let resp = send_raw_expect_error(&path, &oversized);
    let Response::Error { code, message } = resp else {
        panic!("expected error frame, got {resp:?}")
    };
    assert_eq!(code, wire::ERR_PROTOCOL);
    assert!(message.contains("oversized"), "message: {message}");

    // 3. Unknown version.
    let mut bad_version = valid.clone();
    bad_version[2] = 9;
    let resp = send_raw_expect_error(&path, &bad_version);
    let Response::Error { code, message } = resp else {
        panic!("expected error frame, got {resp:?}")
    };
    assert_eq!(code, wire::ERR_PROTOCOL);
    assert!(message.contains("version"), "message: {message}");

    // 4. Garbage magic.
    let resp = send_raw_expect_error(&path, &[0xDEu8; 64]);
    let Response::Error { code, .. } = resp else {
        panic!("expected error frame, got {resp:?}")
    };
    assert_eq!(code, wire::ERR_PROTOCOL);

    assert_eq!(transport.stats().protocol_errors, 4);

    // The batcher was never poisoned: a fresh well-formed client works,
    // and so do serve-level errors on a live connection.
    let mut client = TransportClient::connect(&path).unwrap();
    let mut rng = Rng::seeded(2401);
    let h = unit_vector(&mut rng, d);
    let reply = client.sample(&h, 5, 1).unwrap();
    assert_eq!(reply.draw.len(), 5);

    // A query the sampler rejects (wrong dim) is a *request*-level error
    // (ERR_SERVE): typed, and the connection survives it.
    let err = client.sample(&[1.0f32; 3], 5, 2).unwrap_err();
    match &err {
        ProtocolError::Remote { code, .. } => {
            assert_eq!(*code, wire::ERR_SERVE);
            assert!(!err.closes_connection());
        }
        other => panic!("expected remote serve error, got {other:?}"),
    }
    let reply = client.sample(&h, 5, 3).unwrap();
    assert_eq!(reply.draw.len(), 5);

    // Every well-formed request above flowed through the shared batcher.
    assert!(batcher.stats().requests >= 3);
}

#[test]
fn overload_backpressure_sheds_typed_errors_and_survives() {
    let n = 32;
    let d = 6;
    // A wide, slow batcher window: the blind-written burst below decodes
    // in full while the batcher is still waiting for its batch to fill,
    // so the per-connection in-flight cap is deterministically exceeded.
    let (_offline, _batcher, transport) = serve_stack(
        n,
        d,
        2600,
        BatcherOptions {
            max_batch: 8192,
            max_wait: Duration::from_millis(300),
        },
        "overload",
    );
    // A *foreign* client that writes its whole burst before reading
    // anything (TransportClient::pipeline windows itself below the cap
    // precisely to be immune — so emulate the misbehaving peer by hand).
    // The burst stays under the server's outstanding-reply ceiling and
    // both directions fit the socket buffers, so the blind write cannot
    // deadlock this test.
    let mut rng = Rng::seeded(2601);
    let burst = rfsoftmax::transport::MAX_IN_FLIGHT + 600;
    let mut buf = Vec::new();
    for j in 0..burst {
        wire::encode_request(
            &mut buf,
            1 + j as u64,
            &Request::Probability {
                h: unit_vector(&mut rng, d),
                class: (j % n) as u32,
            },
        );
    }
    let mut stream = UnixStream::connect(transport.path()).unwrap();
    stream.write_all(&buf).unwrap();
    stream.flush().unwrap();
    let mut served = 0usize;
    let mut shed = 0usize;
    for _ in 0..burst {
        let (id, resp) = wire::read_response(&mut stream)
            .expect("typed frame")
            .expect("connection must stay open");
        assert!(id >= 1 && id <= burst as u64);
        match resp {
            Response::Probability { q, .. } => {
                assert!(q.is_finite());
                served += 1;
            }
            Response::Error { code, .. } => {
                assert_eq!(
                    code,
                    wire::ERR_OVERLOAD,
                    "only overload sheds expected"
                );
                shed += 1;
            }
            other => panic!("unexpected response kind: {other:?}"),
        }
    }
    assert_eq!(served + shed, burst);
    assert!(shed > 0, "cap never engaged ({served} served)");
    assert!(
        served >= 1,
        "everything shed — the cap must still serve up to its limit"
    );
    assert_eq!(transport.stats().overloads, shed as u64);
    // The connection survives shedding: a calm follow-up request on the
    // same socket is served.
    let mut again = Vec::new();
    wire::encode_request(
        &mut again,
        99_999,
        &Request::Probability { h: unit_vector(&mut rng, d), class: 3 },
    );
    stream.write_all(&again).unwrap();
    stream.flush().unwrap();
    let (id, resp) = wire::read_response(&mut stream).unwrap().unwrap();
    assert_eq!(id, 99_999);
    assert!(matches!(resp, Response::Probability { .. }));

    // And the windowed TransportClient::pipeline is immune by design: a
    // wave far larger than the cap completes with zero sheds.
    let shed_before = transport.stats().overloads;
    let mut client = TransportClient::connect(transport.path()).unwrap();
    let reqs: Vec<Request> = (0..rfsoftmax::transport::MAX_IN_FLIGHT + 600)
        .map(|j| Request::Probability {
            h: unit_vector(&mut rng, d),
            class: (j % n) as u32,
        })
        .collect();
    let resps = client.pipeline(&reqs).unwrap();
    assert!(resps
        .iter()
        .all(|r| matches!(r, Response::Probability { .. })));
    assert_eq!(
        transport.stats().overloads,
        shed_before,
        "windowed pipeline must never be shed"
    );
}

// ---------------------------------------------------------------------
// TCP transport
// ---------------------------------------------------------------------

/// TCP server + batcher + offline reference over the same sampler state.
fn tcp_serve_stack(
    n: usize,
    d: usize,
    seed: u64,
    opts: BatcherOptions,
) -> (ShardedKernelSampler<RffMap>, Arc<MicroBatcher>, TransportServer) {
    let offline = sharded_rff(n, d, seed);
    let (server, _writer) = SamplerServer::new(offline.fork().unwrap());
    let batcher = Arc::new(MicroBatcher::spawn(server, opts));
    let transport =
        TransportServer::bind_tcp("127.0.0.1:0", Arc::clone(&batcher)).unwrap();
    (offline, batcher, transport)
}

#[test]
fn tcp_loopback_round_trip_all_query_kinds() {
    let n = 48;
    let d = 6;
    let (offline, _batcher, transport) =
        tcp_serve_stack(n, d, 2700, BatcherOptions::default());
    let mut client =
        TransportClient::connect_endpoint(transport.endpoint()).unwrap();
    let mut rng = Rng::seeded(2701);
    for probe in 0..4 {
        let h = unit_vector(&mut rng, d);

        let reply = client.sample(&h, 9, 7100 + probe).unwrap();
        assert_eq!(reply.draw.len(), 9);
        assert_eq!(reply.epoch, 0);
        for (&id, &q) in reply.draw.ids.iter().zip(&reply.draw.probs) {
            assert!((id as usize) < n);
            let want = offline.probability(&h, id as usize);
            assert!(
                (q - want).abs() < 1e-12 * want.max(1e-12),
                "tcp-transported q {q} vs offline {want}"
            );
        }

        let (q, epoch) = client.probability(&h, 11).unwrap();
        assert_eq!(epoch, 0);
        assert!((q - offline.probability(&h, 11)).abs() < 1e-15);

        let (top, epoch) = client.top_k(&h, 5).unwrap();
        assert_eq!(epoch, 0);
        assert_eq!(top, offline.top_k(&h, 5));
    }
    let stats = transport.stats();
    assert_eq!(stats.connections, 1);
    assert_eq!(stats.requests, 12);
    assert_eq!(stats.request_frames, 12, "one frame per sync request");
    assert_eq!(stats.protocol_errors, 0);
}

#[test]
fn tcp_admin_frames_mutate_the_served_universe() {
    let n = 24;
    let d = 6;
    let mut rng = Rng::seeded(2800);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let offline = ShardedKernelSampler::with_map(
        &classes,
        RffMap::new(d, 32, 2.0, &mut Rng::seeded(2801)),
        4,
        "rff-sharded",
    );
    let (server, writer) = SamplerServer::new(offline.fork().unwrap());
    let writer = std::sync::Arc::new(std::sync::Mutex::new(writer));
    let batcher = Arc::new(MicroBatcher::spawn(
        server.clone(),
        BatcherOptions::default(),
    ));
    let admin = Arc::new(std::sync::Mutex::new(
        rfsoftmax::serving::SharedWriterAdmin::new(Arc::clone(&writer), d),
    ));
    let transport = TransportServer::bind_tcp_with_surface(
        "127.0.0.1:0",
        Arc::clone(&batcher),
        admin,
    )
    .unwrap();
    let mut client =
        TransportClient::connect_endpoint(transport.endpoint()).unwrap();

    // Grow by two classes over TCP, retire one, and verify the served
    // universe tracks it exactly.
    let add = Matrix::randn(&mut rng, 2, d).l2_normalized_rows();
    let (ids, epoch) = client.add_classes(&add).unwrap();
    assert_eq!(ids, vec![n as u32, n as u32 + 1]);
    assert_eq!(epoch, 1);
    let epoch = client.retire_classes(&[3]).unwrap();
    assert_eq!(epoch, 2);
    let snap = server.snapshot();
    assert_eq!(snap.sampler().num_classes(), n + 2);
    assert_eq!(snap.sampler().live_classes(), n + 1);
    let h = unit_vector(&mut rng, d);
    let (q, _) = client.probability(&h, 3).unwrap();
    assert_eq!(q, 0.0, "retired class must serve exact zero");
    assert_eq!(transport.stats().admin_requests, 2);
}

#[test]
fn uds_and_tcp_draws_are_byte_identical_for_equal_seeds() {
    let n = 64;
    let d = 8;
    // Two forks of the SAME offline sampler state behind the two socket
    // kinds: the transport must be a pure pipe, so equal (seed, query,
    // epoch) means byte-identical draws across uds and tcp.
    let offline = sharded_rff(n, d, 2900);
    let (uds_server, _w1) = SamplerServer::new(offline.fork().unwrap());
    let uds_batcher =
        Arc::new(MicroBatcher::spawn(uds_server, BatcherOptions::default()));
    let uds = TransportServer::bind(
        sock_path("uds-vs-tcp"),
        Arc::clone(&uds_batcher),
    )
    .unwrap();
    let (tcp_server, _w2) = SamplerServer::new(offline.fork().unwrap());
    let tcp_batcher =
        Arc::new(MicroBatcher::spawn(tcp_server, BatcherOptions::default()));
    let tcp =
        TransportServer::bind_tcp("127.0.0.1:0", Arc::clone(&tcp_batcher))
            .unwrap();
    let mut uds_client = TransportClient::connect(uds.path()).unwrap();
    let mut tcp_client =
        TransportClient::connect_endpoint(tcp.endpoint()).unwrap();
    let mut rng = Rng::seeded(2901);
    for i in 0..12u64 {
        let h = unit_vector(&mut rng, d);
        let a = uds_client.sample(&h, 7, 0xBEE0 + i).unwrap();
        let b = tcp_client.sample(&h, 7, 0xBEE0 + i).unwrap();
        assert_eq!(a.epoch, b.epoch);
        assert_eq!(a.draw, b.draw, "seed {i}: uds and tcp draws diverged");
        let (qa, _) = uds_client.probability(&h, (i as usize) % n).unwrap();
        let (qb, _) = tcp_client.probability(&h, (i as usize) % n).unwrap();
        assert_eq!(qa, qb);
        let (ta, _) = uds_client.top_k(&h, 6).unwrap();
        let (tb, _) = tcp_client.top_k(&h, 6).unwrap();
        assert_eq!(ta, tb);
        assert_eq!(ta, offline.top_k(&h, 6));
    }
}

// ---------------------------------------------------------------------
// Wire v3 batched waves over the transport
// ---------------------------------------------------------------------

#[test]
fn wave_pipeline_amortizes_headers_and_coalesces() {
    let n = 64;
    let d = 8;
    let (offline, batcher, transport) = tcp_serve_stack(
        n,
        d,
        3000,
        BatcherOptions { max_batch: 64, max_wait: Duration::from_millis(1) },
    );
    let mut client =
        TransportClient::connect_endpoint(transport.endpoint()).unwrap();
    let mut rng = Rng::seeded(3001);
    let burst = 64usize;
    let wave = 16usize;
    let reqs: Vec<Request> = (0..burst)
        .map(|j| {
            let h = unit_vector(&mut rng, d);
            match j % 3 {
                0 => Request::Sample { h, m: 5, seed: 0x3000 + j as u64 },
                1 => Request::Probability { h, class: (j % n) as u32 },
                _ => Request::TopK { h, k: 4 },
            }
        })
        .collect();
    let resps = client.pipeline_waves(&reqs, wave).unwrap();
    assert_eq!(resps.len(), burst);
    // Snapshot batcher stats BEFORE the verification loop below issues
    // its own direct (uncoalesced) cross-check requests.
    let bstats = batcher.stats();
    let (batched_requests, batches) = (bstats.requests, bstats.batches);
    for (req, resp) in reqs.iter().zip(&resps) {
        match (req, resp) {
            (Request::Sample { h, m, seed }, Response::Sample { ids, probs, .. }) => {
                assert_eq!(ids.len(), *m as usize);
                assert_eq!(probs.len(), *m as usize);
                // Byte-identical to a sync call with the same seed (the
                // snapshot never swapped: no writer in this stack).
                let direct = batcher.sample(h, *m as usize, *seed);
                assert_eq!(ids, &direct.draw.ids);
                assert_eq!(probs, &direct.draw.probs);
            }
            (Request::Probability { h, class }, Response::Probability { q, .. }) => {
                assert_eq!(*q, offline.probability(h, *class as usize));
            }
            (Request::TopK { h, k }, Response::TopK { items, .. }) => {
                assert_eq!(items, &offline.top_k(h, *k as usize));
            }
            other => panic!("kind mismatch: {other:?}"),
        }
    }
    let stats = transport.stats();
    // Header amortization, request direction: 64 requests rode in
    // exactly 64/16 = 4 wave frames.
    assert_eq!(stats.requests, burst as u64);
    assert_eq!(stats.request_frames, (burst / wave) as u64);
    assert_eq!(stats.wave_frames, (burst / wave) as u64);
    // The client parsed fewer response frames than responses whenever
    // the server packed replies (never more than one frame each).
    let fs = client.frame_stats();
    assert_eq!(fs.resp_items, burst as u64);
    assert!(fs.resp_frames <= fs.resp_items);
    // One decoded wave lands as one coalesced batch: with waves of 16
    // and max_batch 64, the serve path must have coalesced.
    assert_eq!(batched_requests, burst as u64);
    let mean_batch = batched_requests as f64 / batches.max(1) as f64;
    assert!(
        mean_batch >= wave as f64 / 2.0,
        "wave submission did not coalesce: mean batch {mean_batch:.2}"
    );
}

#[test]
fn overload_sheds_whole_waves_never_split() {
    let n = 32;
    let d = 6;
    // Wide, slow batcher window (as in the single-frame overload test):
    // the blind-written burst decodes in full while the batcher is still
    // waiting, so the cap is deterministically reached before the wave
    // frame arrives.
    let (_offline, _batcher, transport) = tcp_serve_stack(
        n,
        d,
        3100,
        BatcherOptions { max_batch: 8192, max_wait: Duration::from_millis(300) },
    );
    let mut rng = Rng::seeded(3101);
    let cap = rfsoftmax::transport::MAX_IN_FLIGHT;
    let wave = 16usize;
    let mut buf = Vec::new();
    // Fill the in-flight cap with singles…
    for j in 0..cap {
        wire::encode_request(
            &mut buf,
            1 + j as u64,
            &Request::Probability {
                h: unit_vector(&mut rng, d),
                class: (j % n) as u32,
            },
        );
    }
    // …then one wave: with the cap already reached, the whole wave must
    // shed as ERR_OVERLOAD — all 16 sub-requests, no partial admit.
    let wave_reqs: Vec<Request> = (0..wave)
        .map(|j| Request::Probability {
            h: unit_vector(&mut rng, d),
            class: (j % n) as u32,
        })
        .collect();
    let wave_items: Vec<(u64, &Request)> = wave_reqs
        .iter()
        .enumerate()
        .map(|(j, r)| (100_000 + j as u64, r))
        .collect();
    wire::encode_request_wave(&mut buf, &wave_items);
    let mut stream = std::net::TcpStream::connect(match transport.endpoint() {
        rfsoftmax::transport::Endpoint::Tcp(a) => *a,
        other => panic!("expected tcp endpoint, got {other}"),
    })
    .unwrap();
    stream.write_all(&buf).unwrap();
    stream.flush().unwrap();
    // Sending a wave flips the connection to v3 replies: read frames
    // (singles or packed waves) until every response arrived.
    let mut served = 0usize;
    let mut wave_sheds = 0usize;
    let mut seen = 0usize;
    while seen < cap + wave {
        let frame = wire::read_response_frame(&mut stream)
            .expect("typed frame")
            .expect("connection must stay open");
        let items = match frame {
            wire::ResponseFrame::Single(id, resp) => vec![(id, resp)],
            wire::ResponseFrame::Wave(subs) => subs,
        };
        for (id, resp) in items {
            seen += 1;
            match resp {
                Response::Probability { q, .. } => {
                    assert!(q.is_finite());
                    assert!(id <= cap as u64, "wave sub-request was admitted");
                    served += 1;
                }
                Response::Error { code, .. } => {
                    assert_eq!(code, wire::ERR_OVERLOAD);
                    assert!(
                        id >= 100_000,
                        "a single was shed before the wave arrived"
                    );
                    wave_sheds += 1;
                }
                other => panic!("unexpected response kind: {other:?}"),
            }
        }
    }
    assert_eq!(served, cap, "every single below the cap must be served");
    assert_eq!(
        wave_sheds, wave,
        "the wave must shed whole — all sub-requests or none"
    );
    assert_eq!(transport.stats().overloads, wave as u64);
}

#[test]
fn v2_single_frame_client_is_served_by_a_v3_server() {
    let n = 32;
    let d = 6;
    let (_offline, _batcher, transport) =
        serve_stack(n, d, 3200, BatcherOptions::default(), "v2-interop");
    // A v2 peer's frames are byte-identical to our single-frame encoding
    // (which pins version 2); hand-roll one and verify both that it is
    // served and that the reply comes back stamped v2 so the v2 peer
    // can decode it.
    let mut rng = Rng::seeded(3201);
    let mut buf = Vec::new();
    wire::encode_request(
        &mut buf,
        9,
        &Request::Probability { h: unit_vector(&mut rng, d), class: 5 },
    );
    assert_eq!(buf[2], 2, "single-frame encoding must stay v2");
    let mut stream = UnixStream::connect(transport.path()).unwrap();
    stream.write_all(&buf).unwrap();
    stream.flush().unwrap();
    let mut head = [0u8; wire::HEADER_LEN];
    std::io::Read::read_exact(&mut stream, &mut head).unwrap();
    assert_eq!(&head[0..2], b"RF");
    assert_eq!(head[2], 2, "reply to a v2 single must be stamped v2");
    // And a v3-stamped single (same bytes, bumped version) is accepted
    // too — the server speaks 2..=3.
    let mut v3 = Vec::new();
    wire::encode_request(
        &mut v3,
        10,
        &Request::Probability { h: unit_vector(&mut rng, d), class: 6 },
    );
    v3[2] = 3;
    let mut stream = UnixStream::connect(transport.path()).unwrap();
    stream.write_all(&v3).unwrap();
    stream.flush().unwrap();
    let (id, resp) = wire::read_response(&mut stream).unwrap().unwrap();
    assert_eq!(id, 10);
    assert!(matches!(resp, Response::Probability { .. }));
}

// ---------------------------------------------------------------------
// STATS telemetry scrape (wire v3 admin family)
// ---------------------------------------------------------------------

#[test]
fn stats_frame_scrapes_reconciling_telemetry_over_uds_and_tcp() {
    let n = 48;
    let d = 6;
    for use_tcp in [false, true] {
        let (_offline, _batcher, transport) = if use_tcp {
            tcp_serve_stack(n, d, 3400, BatcherOptions::default())
        } else {
            serve_stack(n, d, 3400, BatcherOptions::default(), "stats")
        };
        let mut client = TransportClient::connect_endpoint(transport.endpoint()).unwrap();
        let mut rng = Rng::seeded(3401);
        for i in 0..10u64 {
            let h = unit_vector(&mut rng, d);
            client.sample(&h, 5, 0x57A7 + i).unwrap();
        }
        for i in 0..5 {
            let h = unit_vector(&mut rng, d);
            client.probability(&h, i % n).unwrap();
            client.top_k(&h, 4).unwrap();
        }
        let text = client.stats().unwrap();
        let j = rfsoftmax::json::parse(&text).unwrap();
        let count = |path: &[&str]| j.at(path).and_then(|v| v.as_i64());
        assert_eq!(count(&["batcher", "requests"]), Some(20));
        assert_eq!(count(&["batcher", "samples"]), Some(10));
        assert_eq!(count(&["batcher", "probabilities"]), Some(5));
        assert_eq!(count(&["batcher", "top_ks"]), Some(5));
        // Stage counts reconcile with the request total: batch-shared
        // stages record one share per request, and the transport stages
        // record one point per serve frame decoded / response encoded.
        for stage in
            ["decode", "queue_wait", "coalesce", "gemm_wave", "tree_walk", "encode_reply"]
        {
            assert_eq!(
                count(&["telemetry", "stages", stage, "count"]),
                Some(20),
                "stage {stage} does not reconcile (tcp={use_tcp})"
            );
        }
        assert_eq!(j.at(&["telemetry", "enabled"]).and_then(|v| v.as_bool()), Some(true));
        let slowest = j
            .at(&["telemetry", "slowest"])
            .and_then(|v| v.as_array().map(|a| a.len()))
            .unwrap_or(0);
        assert!(slowest > 0, "slow-request log must have entries after 20 requests");
        // The transport section reports the scrape itself too (counted
        // as an admin frame before the JSON is built).
        assert_eq!(count(&["transport", "requests"]), Some(20));
        assert_eq!(count(&["transport", "admin_requests"]), Some(1));
        // Read-only and repeatable: the connection survives, and a
        // second scrape sees its predecessor in the admin counter.
        let j2 = rfsoftmax::json::parse(&client.stats().unwrap()).unwrap();
        assert_eq!(j2.at(&["transport", "admin_requests"]).and_then(|v| v.as_i64()), Some(2));
        assert_eq!(transport.stats().protocol_errors, 0);
    }
}

#[test]
fn v2_stamped_stats_frame_gets_the_unknown_kind_refusal() {
    let n = 32;
    let d = 6;
    let (_offline, _batcher, transport) =
        serve_stack(n, d, 3500, BatcherOptions::default(), "stats-v2");
    let path = transport.path().to_path_buf();
    // A STATS request is stamped v3 by construction…
    let mut buf = Vec::new();
    wire::encode_request(&mut buf, 7, &Request::Stats);
    assert_eq!(buf[2], 3, "STATS frames must be stamped wire v3");
    // …and the same bytes stamped v2 must draw the identical refusal a
    // genuine v2 peer (which predates the kind) would produce.
    buf[2] = 2;
    let resp = send_raw_expect_error(&path, &buf);
    let Response::Error { code, message } = resp else {
        panic!("expected error frame, got {resp:?}")
    };
    assert_eq!(code, wire::ERR_PROTOCOL);
    assert!(message.contains("kind"), "message: {message}");
    // The refusal never poisons the server: a fresh v3 client scrapes.
    let mut client = TransportClient::connect(&path).unwrap();
    let text = client.stats().unwrap();
    assert!(rfsoftmax::json::parse(&text).is_ok());
}

#[test]
fn tcp_server_shutdown_closes_connections_cleanly() {
    let n = 24;
    let d = 6;
    let (_offline, _batcher, transport) =
        tcp_serve_stack(n, d, 3300, BatcherOptions::default());
    let endpoint = transport.endpoint().clone();
    let mut client = TransportClient::connect_endpoint(&endpoint).unwrap();
    let mut rng = Rng::seeded(3301);
    let h = unit_vector(&mut rng, d);
    assert_eq!(client.sample(&h, 4, 1).unwrap().draw.len(), 4);
    drop(transport);
    // The listener is gone and the connection is dead.
    assert!(client.sample(&h, 4, 2).is_err());
}

#[test]
fn server_shutdown_closes_connections_cleanly() {
    let n = 24;
    let d = 6;
    let (_offline, _batcher, transport) =
        serve_stack(n, d, 2500, BatcherOptions::default(), "shutdown");
    let path = transport.path().to_path_buf();
    let mut client = TransportClient::connect(&path).unwrap();
    let mut rng = Rng::seeded(2501);
    let h = unit_vector(&mut rng, d);
    assert_eq!(client.sample(&h, 4, 1).unwrap().draw.len(), 4);
    drop(transport);
    // The socket file is gone and the connection is dead.
    assert!(!path.exists(), "socket file must be removed on shutdown");
    assert!(client.sample(&h, 4, 2).is_err());
}
