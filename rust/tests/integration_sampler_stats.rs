//! Statistical integration tests across the sampler stack: empirical
//! sampling frequencies vs claimed probabilities (χ²-style), cross-sampler
//! distribution agreement, and the RF-softmax ↔ softmax approximation
//! quality that Theorem 2 promises — run at realistic sizes.

use rfsoftmax::config::FeatureMapKind;
use rfsoftmax::featmap::{QuadraticMap, RffMap};
use rfsoftmax::linalg::{dot, softmax, unit_vector, Matrix, QuantizeKind};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{
    BucketKernelSampler, KernelTree, QuadraticSampler, RffSampler, Sampler,
    ShardedKernelSampler,
};

fn normalized(rng: &mut Rng, n: usize, d: usize) -> Matrix {
    Matrix::randn(rng, n, d).l2_normalized_rows()
}

/// Total-variation distance between a sampler's q and the softmax p.
fn tv_to_softmax(s: &dyn Sampler, classes: &Matrix, h: &[f32], tau: f32) -> f64 {
    let n = classes.rows();
    let logits: Vec<f64> = (0..n)
        .map(|i| (tau * dot(h, classes.row(i))) as f64)
        .collect();
    let p = softmax(&logits);
    let mut tv = 0.0;
    for i in 0..n {
        tv += (s.probability(h, i) - p[i]).abs();
    }
    tv / 2.0
}

#[test]
fn rff_tv_distance_decreases_with_d() {
    // Theorem 2: q → p as D grows (ν = τ). TV(q, p) must fall with D.
    let mut rng = Rng::seeded(901);
    let n = 256;
    let d = 24;
    let tau = 3.0;
    let classes = normalized(&mut rng, n, d);
    let h = unit_vector(&mut rng, d);
    let mut prev = f64::INFINITY;
    for nf in [32usize, 256, 2048] {
        // Average a few maps to tame map-to-map variance.
        let mut tv = 0.0;
        for rep in 0..3 {
            let mut map_rng = Rng::seeded(1000 + nf as u64 * 7 + rep);
            let s = RffSampler::new(&classes, nf, tau, &mut map_rng);
            tv += tv_to_softmax(&s, &classes, &h, tau);
        }
        tv /= 3.0;
        assert!(
            tv < prev * 1.05,
            "TV did not decrease: D={nf} gave {tv} (prev {prev})"
        );
        prev = tv;
    }
    assert!(prev < 0.25, "TV at D=2048 still large: {prev}");
}

#[test]
fn bucket_and_tree_quadratic_agree() {
    // The bucketed sampler must match the full-tree sampler's
    // distribution for the (exactly linearized) quadratic kernel.
    let mut rng = Rng::seeded(902);
    let n = 300;
    let d = 12;
    let classes = normalized(&mut rng, n, d);
    let tree = QuadraticSampler::new(&classes, 100.0, 1.0);
    let bucket = BucketKernelSampler::with_map(
        &classes,
        QuadraticMap::new(d, 100.0, 1.0),
        32,
        "quadratic-bucket",
    );
    let h = unit_vector(&mut rng, d);
    for i in (0..n).step_by(7) {
        let a = tree.probability(&h, i);
        let b = bucket.probability(&h, i);
        assert!(
            (a - b).abs() < 5e-3 * a.max(b).max(1e-9),
            "class {i}: tree {a} vs bucket {b}"
        );
    }
}

#[test]
fn empirical_frequencies_match_probabilities_at_scale() {
    // n = 5000 classes, 200k draws through the memoized batch path.
    let mut rng = Rng::seeded(903);
    let n = 5000;
    let dim = 64;
    let mut tree = KernelTree::new(n, dim, 1e-8);
    let mut phi = vec![0.0f32; dim];
    for i in 0..n {
        for v in phi.iter_mut() {
            *v = rng.f32() + 0.01; // nonnegative → no clamping path
        }
        tree.add_leaf(i, &phi);
    }
    let z: Vec<f32> = (0..dim).map(|_| rng.f32() + 0.01).collect();
    let trials = 200_000;
    let (ids, _) = tree.sample_many(&z, trials, &mut rng);
    let mut counts = vec![0u32; n];
    for &i in &ids {
        counts[i as usize] += 1;
    }
    // Check the head classes (largest q) precisely and the aggregate χ².
    let mut chi2 = 0.0;
    let mut dof = 0;
    for i in 0..n {
        let q = tree.probability(&z, i);
        let e = q * trials as f64;
        if e >= 5.0 {
            let o = counts[i] as f64;
            chi2 += (o - e) * (o - e) / e;
            dof += 1;
        }
    }
    // χ² concentration: mean ≈ dof, sd ≈ √(2·dof); allow 6σ.
    let bound = dof as f64 + 6.0 * (2.0 * dof as f64).sqrt();
    assert!(
        chi2 < bound,
        "χ² = {chi2:.1} over {dof} cells exceeds {bound:.1}"
    );
}

/// χ² goodness-of-fit of a sampler's `sample_batch` draws against its own
/// `probability()` claims, per example, conditioned on `≠ target`.
fn chi2_batch_vs_probability(
    sampler: &dyn Sampler,
    h: &Matrix,
    targets: &[u32],
    per_call_m: usize,
    reps: usize,
    rng: &mut Rng,
) {
    let n = sampler.num_classes();
    let bsz = h.rows();
    let mut counts = vec![vec![0usize; n]; bsz];
    for _ in 0..reps {
        let batch = sampler.sample_batch(h, targets, per_call_m, rng);
        assert_eq!(batch.batch(), bsz);
        for (b, draw) in batch.draws.iter().enumerate() {
            assert_eq!(draw.len(), per_call_m);
            for &id in &draw.ids {
                counts[b][id as usize] += 1;
            }
        }
    }
    let trials = (reps * per_call_m) as f64;
    for b in 0..bsz {
        let t = targets[b] as usize;
        assert_eq!(counts[b][t], 0, "example {b} drew its own target");
        let q_t = sampler.probability(h.row(b), t);
        let renorm = 1.0 - q_t;
        let mut chi2 = 0.0;
        let mut dof = 0usize;
        for i in 0..n {
            if i == t {
                continue;
            }
            let q = sampler.probability(h.row(b), i) / renorm;
            let e = q * trials;
            if e >= 5.0 {
                let o = counts[b][i] as f64;
                chi2 += (o - e) * (o - e) / e;
                dof += 1;
            }
        }
        assert!(dof > 5, "example {b}: too few testable cells ({dof})");
        // χ² concentration: mean ≈ dof, sd ≈ √(2·dof); allow 6σ.
        let bound = dof as f64 + 6.0 * (2.0 * dof as f64).sqrt();
        assert!(
            chi2 < bound,
            "example {b}: χ² = {chi2:.1} over {dof} cells exceeds {bound:.1}"
        );
    }
}

fn batch_queries(rng: &mut Rng, bsz: usize, d: usize) -> Matrix {
    let mut h = Matrix::zeros(bsz, d);
    for b in 0..bsz {
        let v = unit_vector(rng, d);
        h.row_mut(b).copy_from_slice(&v);
    }
    h
}

#[test]
fn batched_rff_draws_match_claimed_probabilities() {
    // The batch path (gemm φ + parallel fan-out + rejection) must
    // reproduce probability() per example — χ² at 20k draws/example.
    let mut rng = Rng::seeded(906);
    let n = 48;
    let d = 10;
    let classes = normalized(&mut rng, n, d);
    let sampler = RffSampler::new(&classes, 256, 2.0, &mut rng);
    let h = batch_queries(&mut rng, 4, d);
    let targets = [0u32, 11, 23, 47];
    chi2_batch_vs_probability(&sampler, &h, &targets, 50, 400, &mut rng);
}

#[test]
fn batched_sharded_draws_match_claimed_probabilities() {
    let mut rng = Rng::seeded(907);
    let n = 48;
    let d = 10;
    let classes = normalized(&mut rng, n, d);
    let sampler = ShardedKernelSampler::with_map(
        &classes,
        RffMap::new(d, 256, 2.0, &mut Rng::seeded(908)),
        8,
        "rff-sharded",
    );
    let h = batch_queries(&mut rng, 4, d);
    let targets = [3u32, 17, 29, 41];
    chi2_batch_vs_probability(&sampler, &h, &targets, 50, 400, &mut rng);
}

#[test]
fn sharded_probabilities_are_exact_over_all_classes() {
    // Exactness: the two-level (shard → leaf) probabilities form a true
    // pmf — Σ_i q_i = 1 — for shard counts spanning degenerate
    // single-class tails through a monolithic single shard.
    let mut rng = Rng::seeded(909);
    let n = 321; // non-power-of-two, exercises ragged tail shards
    let d = 12;
    let classes = normalized(&mut rng, n, d);
    let h = unit_vector(&mut rng, d);
    for &shards in &[1usize, 2, 8, 64, 512] {
        let s = ShardedKernelSampler::with_map(
            &classes,
            RffMap::new(d, 64, 2.0, &mut Rng::seeded(910)),
            shards,
            "rff-sharded",
        );
        let total: f64 = (0..n).map(|i| s.probability(&h, i)).sum();
        assert!(
            (total - 1.0).abs() < 1e-6,
            "S={shards}: Σq = {total}"
        );
        // And the sharded q agrees with itself under sampling: each
        // draw's reported probability equals the probability query.
        let mut r = Rng::seeded(911);
        let draw = s.sample(&h, 64, &mut r);
        for (&id, &q) in draw.ids.iter().zip(&draw.probs) {
            let want = s.probability(&h, id as usize);
            assert!(
                (q - want).abs() < 1e-12,
                "S={shards} id {id}: {q} vs {want}"
            );
        }
    }
}

#[test]
fn quantized_sampler_distributions_stay_within_bias_budget() {
    // Storing the sampler's private class copy in f16/i8
    // (`sampler.quantize`) must not move the sampled distribution
    // outside the bias budget the RFF approximation already carries.
    // Three obligations per mode:
    //  1. Σq stays an exact pmf (tree sums are built from the
    //     *dequantized* rows, so q remains the walk's exact law);
    //  2. TV(q_quant, q_f32) stays far below the TV(q_f32, p) scale —
    //     f16 at round-off, i8 at percent level;
    //  3. χ² of the quantized sampler's draws against its own claimed
    //     probabilities passes at 60k draws (exact self-consistency
    //     survives quantization).
    let mut rng = Rng::seeded(940);
    let n = 256;
    let d = 16;
    let tau = 2.0;
    let classes = normalized(&mut rng, n, d);
    let h = unit_vector(&mut rng, d);
    let build = |qk: QuantizeKind| {
        RffSampler::with_kind_opts(
            &classes,
            256,
            tau,
            FeatureMapKind::Rff,
            &mut Rng::seeded(941),
            0,
            qk,
        )
    };
    let full = build(QuantizeKind::None);
    let full_tv_p = tv_to_softmax(&full, &classes, &h, tau);
    for (qk, budget) in [(QuantizeKind::F16, 5e-3), (QuantizeKind::I8, 8e-2)] {
        let s = build(qk);
        let mut tv = 0.0;
        let mut total = 0.0;
        for i in 0..n {
            let q = s.probability(&h, i);
            tv += (q - full.probability(&h, i)).abs();
            total += q;
        }
        tv /= 2.0;
        assert!((total - 1.0).abs() < 1e-6, "{}: Σq = {total}", qk.name());
        assert!(tv < budget, "{}: TV vs f32 = {tv} ≥ {budget}", qk.name());
        // The softmax-approximation budget is intact: quantization adds
        // at most its own drift on top of the f32 sampler's TV to p.
        let tv_p = tv_to_softmax(&s, &classes, &h, tau);
        assert!(
            tv_p < full_tv_p + budget,
            "{}: TV to softmax {tv_p} vs f32's {full_tv_p} + {budget}",
            qk.name()
        );

        let trials = 60_000;
        let mut draw_rng = Rng::seeded(942);
        let draw = s.sample(&h, trials, &mut draw_rng);
        let mut counts = vec![0u32; n];
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
        let mut chi2 = 0.0;
        let mut dof = 0usize;
        for i in 0..n {
            let e = s.probability(&h, i) * trials as f64;
            if e >= 5.0 {
                let o = counts[i] as f64;
                chi2 += (o - e) * (o - e) / e;
                dof += 1;
            }
        }
        assert!(dof > 50, "{}: too few testable cells ({dof})", qk.name());
        // χ² concentration: mean ≈ dof, sd ≈ √(2·dof); allow 6σ.
        let bound = dof as f64 + 6.0 * (2.0 * dof as f64).sqrt();
        assert!(
            chi2 < bound,
            "{}: χ² = {chi2:.1} over {dof} cells exceeds {bound:.1}",
            qk.name()
        );
    }
}

#[test]
fn update_stream_keeps_distribution_consistent() {
    // Simulate training-like churn: 2000 embedding updates, then verify
    // the tree still matches a fresh rebuild (drift bound).
    let mut rng = Rng::seeded(904);
    let n = 400;
    let d = 16;
    let mut classes = normalized(&mut rng, n, d);
    let mut sampler = RffSampler::new(&classes, 128, 2.0, &mut Rng::seeded(77));
    for _ in 0..2000 {
        let i = rng.index(n);
        let e = unit_vector(&mut rng, d);
        sampler.update_class(i, &e);
        classes.row_mut(i).copy_from_slice(&e);
    }
    let fresh = RffSampler::new(&classes, 128, 2.0, &mut Rng::seeded(77));
    let h = unit_vector(&mut rng, d);
    for i in (0..n).step_by(13) {
        let a = sampler.probability(&h, i);
        let b = fresh.probability(&h, i);
        assert!(
            (a - b).abs() < 1e-3 * a.max(b).max(1e-6),
            "drift after 2000 updates at class {i}: {a} vs {b}"
        );
    }
}

#[test]
fn adjusted_partition_estimate_unbiased_under_kernel_q() {
    // eq. 5 end-to-end: with q from a kernel sampling tree (clamps,
    // ε-floor and all), E[Z′] must equal Z because q is the *exact*
    // sampling probability of the procedure. The quadratic kernel keeps
    // the importance weights e^o/q bounded, so the Monte-Carlo mean
    // converges at a testable rate (an RFF q at small D has heavy-tailed
    // weights — unbiased but impractically slow to verify; that estimator
    // is exercised distributionally by `rff_tv_distance_decreases_with_d`).
    let mut rng = Rng::seeded(905);
    let n = 64;
    let d = 12;
    let tau = 2.0;
    let classes = normalized(&mut rng, n, d);
    let sampler = QuadraticSampler::new(&classes, 100.0, 1.0);
    let h = unit_vector(&mut rng, d);
    let logits: Vec<f64> = (0..n)
        .map(|i| (tau * dot(&h, classes.row(i))) as f64)
        .collect();
    let t = 0usize;
    let z_true: f64 = logits.iter().map(|o| o.exp()).sum();
    let m = 20;
    let trials = 4000;
    let mut acc = 0.0;
    for _ in 0..trials {
        let draw = sampler.sample_negatives(&h, t, m, &mut rng);
        let negs: Vec<f64> =
            draw.ids.iter().map(|&i| logits[i as usize]).collect();
        let s = rfsoftmax::softmax::sampled_softmax_loss(
            logits[t], &negs, &draw.probs,
        );
        acc += s.z_estimate;
    }
    let z_hat = acc / trials as f64;
    assert!(
        (z_hat - z_true).abs() / z_true < 0.03,
        "E[Z′] = {z_hat:.4} vs Z = {z_true:.4}"
    );
}
