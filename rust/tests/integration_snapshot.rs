//! Durability integration: the full snapshot path — capture under
//! load, manifest-tracked save, crash, restore into a fresh skeleton —
//! must be invisible to the served distribution. Two contracts:
//!
//! 1. a server that churns, snapshots, dies, and restores, then keeps
//!    churning, is **exactly** the server that never died: bit-equal
//!    probabilities, identical live/total accounting, and χ²-consistent
//!    draws against the never-restarted twin;
//! 2. on-disk corruption (truncation, flipped bytes, future version)
//!    surfaces as typed [`SnapshotError`]s — never a panic, never a
//!    silently-wrong sampler.

use rfsoftmax::featmap::RffMap;
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::ShardedKernelSampler;
use rfsoftmax::serving::{SamplerServer, SamplerWriter};
use rfsoftmax::snapshot::{self, SnapshotError};
use std::path::PathBuf;
use std::sync::Arc;

const N: usize = 40;
const D: usize = 6;
const SEED: u64 = 4100;

fn snap_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rfsm-snap-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One serving stack over a fork of a deterministically-built sharded
/// RFF sampler — called twice with the same seed, it yields
/// byte-identical cold states (same classes, same feature map).
fn stack() -> (SamplerServer, SamplerWriter) {
    let mut rng = Rng::seeded(SEED);
    let classes = Matrix::randn(&mut rng, N, D).l2_normalized_rows();
    let offline = ShardedKernelSampler::with_map(
        &classes,
        RffMap::new(D, 32, 2.0, &mut Rng::seeded(SEED + 1)),
        2,
        "rff-sharded",
    );
    SamplerServer::new(offline.fork().unwrap())
}

/// One deterministic churn round, applied identically to whichever
/// writer is passed in: grow by three, retire two, publish — with a
/// couple of reads in between so the snapshot machinery runs under
/// load, not in a quiesced gap.
fn churn_round(
    server: &SamplerServer,
    writer: &mut SamplerWriter,
    round: u64,
) -> Vec<u32> {
    let mut rng = Rng::seeded(SEED + 10 + round);
    let h = unit_vector(&mut rng, D);
    let mut draw_rng = Rng::seeded(SEED + 20 + round);
    let _ = server.sample(&h, 4, &mut draw_rng);

    let mut emb = Matrix::zeros(3, D);
    for r in 0..3 {
        emb.row_mut(r).copy_from_slice(&unit_vector(&mut rng, D));
    }
    let ids = writer.apply_add_classes(emb).unwrap();
    writer
        .apply_retire_classes(vec![(2 * round + 1) as u32, (2 * round + 6) as u32])
        .unwrap();
    writer.publish();

    let _ = server.sample(&h, 4, &mut draw_rng);
    ids
}

#[test]
fn crash_restart_agrees_with_a_never_restarted_twin() {
    let dir = snap_dir("crash");

    // Two identical stacks; only `main` will crash.
    let (main_server, mut main_writer) = stack();
    let (twin_server, mut twin_writer) = stack();

    // Round 0 on both, then capture main's durable state mid-life.
    let ids_main = churn_round(&main_server, &mut main_writer, 0);
    let ids_twin = churn_round(&twin_server, &mut twin_writer, 0);
    assert_eq!(ids_main, ids_twin, "deterministic id assignment broke");

    let snap = main_server.snapshot_state().expect("sharded kind snapshots");
    let epoch_at_snap = main_server.epoch();
    assert_eq!(snap.epoch, epoch_at_snap);
    let meta = snapshot::save_with_manifest(&dir, "main", &snap).unwrap();
    assert_eq!(meta.epoch, epoch_at_snap);

    // Crash: the entire serving stack goes away.
    drop(main_writer);
    drop(main_server);

    // Restore: cold skeleton (the same construction recipe), state
    // replaced wholesale from disk, published as one epoch swap.
    let (server, mut writer) = stack();
    let loaded = snapshot::load_with_manifest(&dir, "main").unwrap();
    assert_eq!(loaded, snap, "disk round trip must be lossless");
    writer.apply_restore(Arc::new(loaded.state)).unwrap();
    writer.publish();

    // Keep living: an identical post-restore churn round on both.
    let ids_restored = churn_round(&server, &mut writer, 1);
    let ids_twin2 = churn_round(&twin_server, &mut twin_writer, 1);
    assert_eq!(
        ids_restored, ids_twin2,
        "restored state re-assigns different ids than the unbroken twin"
    );

    // Exact accounting: same universe size, same live set, and the
    // twin's growth history is fully reflected (N + 2 rounds × 3 adds).
    let restored = server.snapshot();
    let twin = twin_server.snapshot();
    assert_eq!(restored.sampler().num_classes(), N + 6);
    assert_eq!(restored.sampler().num_classes(), twin.sampler().num_classes());
    assert_eq!(restored.sampler().live_classes(), N + 6 - 4);
    assert_eq!(
        restored.sampler().live_classes(),
        twin.sampler().live_classes()
    );

    // Bit-equal distribution: restore is a wholesale state replacement,
    // so every probability — live, retired-to-zero, or grown — must
    // match the twin exactly, not approximately.
    let mut rng = Rng::seeded(SEED + 99);
    let h = unit_vector(&mut rng, D);
    let total = restored.sampler().num_classes();
    for class in 0..total {
        let got = server.probability(&h, class);
        let want = twin_server.probability(&h, class);
        assert_eq!(got, want, "class {class}: {got} vs twin {want}");
    }

    // χ² draw agreement: restored-server draw counts against the
    // twin's distribution. 600 draws of 8 over ~42 live classes.
    let (bursts, m) = (600usize, 8usize);
    let mut counts = vec![0usize; total];
    let mut draw_rng = Rng::seeded(SEED + 123);
    for _ in 0..bursts {
        let (draw, _) = server.sample(&h, m, &mut draw_rng);
        for &id in &draw.ids {
            counts[id as usize] += 1;
        }
    }
    let trials = (bursts * m) as f64;
    for class in 0..total {
        let q = twin_server.probability(&h, class);
        let expect = trials * q;
        let sd = (trials * q * (1.0 - q)).sqrt().max(1.0);
        assert!(
            (counts[class] as f64 - expect).abs() <= 5.0 * sd + 3.0,
            "class {class}: restored count {} vs twin expectation {expect:.1}",
            counts[class]
        );
        if q == 0.0 {
            assert_eq!(counts[class], 0, "retired class {class} drawn");
        }
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_snapshots_fail_with_typed_errors_never_panics() {
    let dir = snap_dir("corrupt");
    let (server, mut writer) = stack();
    churn_round(&server, &mut writer, 0);
    let snap = server.snapshot_state().unwrap();
    let path = dir.join("state.rfsnap");
    snapshot::write_file(&path, &snap).unwrap();
    let good = std::fs::read(&path).unwrap();
    assert_eq!(snapshot::read_file(&path).unwrap(), snap);

    // Truncated: cut mid-payload (keeping the checksum-sized tail so
    // the length preflight passes and the codec itself must cope).
    std::fs::write(&path, &good[..good.len() / 2]).unwrap();
    match snapshot::read_file(&path) {
        Err(
            SnapshotError::Truncated
            | SnapshotError::BadChecksum { .. }
            | SnapshotError::Malformed(_),
        ) => {}
        other => panic!("truncated file must fail typed, got {other:?}"),
    }

    // Flipped byte mid-payload: the FNV trailer catches it before any
    // parse can wander.
    let mut flipped = good.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    std::fs::write(&path, &flipped).unwrap();
    match snapshot::read_file(&path) {
        Err(SnapshotError::BadChecksum { stored, computed }) => {
            assert_ne!(stored, computed);
        }
        other => panic!("flipped byte must fail the checksum, got {other:?}"),
    }

    // Future version: bytes 8..12 hold the format version; a newer
    // writer's file reports FutureVersion (actionable: upgrade) rather
    // than BadChecksum (misleading: looks like corruption).
    let mut future = good.clone();
    future[8..12].copy_from_slice(&999u32.to_le_bytes());
    std::fs::write(&path, &future).unwrap();
    match snapshot::read_file(&path) {
        Err(SnapshotError::FutureVersion { found, max }) => {
            assert_eq!(found, 999);
            assert!(max < 999);
        }
        other => panic!("future version must be typed, got {other:?}"),
    }

    // Garbage and absence: still typed.
    std::fs::write(&path, b"not a snapshot at all").unwrap();
    assert!(matches!(
        snapshot::read_file(&path),
        Err(SnapshotError::Truncated | SnapshotError::BadMagic)
    ));
    assert!(matches!(
        snapshot::read_file(&dir.join("missing.rfsnap")),
        Err(SnapshotError::Io(_))
    ));

    let _ = std::fs::remove_dir_all(&dir);
}
