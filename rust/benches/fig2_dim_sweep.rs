//! Figure 2 reproduction: validation perplexity on the PTB-scale corpus
//! for RF-softmax with varying feature dimension D (m = 100, T = 0.5).
//!
//! Paper shape: quality improves monotonically with D, approaching the
//! FULL/EXP curve as D grows (Theorem 2: the q↔p approximation tightens
//! as √D).
//!
//! Run: `cargo bench --bench fig2_dim_sweep`

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::coordinator::harness::{
    bench_steps, config_from, curves_table, train_once,
};
use rfsoftmax::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bench_header("F2", "RF-softmax D sweep on PTB (paper Figure 2)");
    let runtime = Runtime::native();
    let steps = bench_steps(400);
    let eval_every = (steps / 4).max(1);

    let mut runs = Vec::new();
    for d in ["64", "256", "1024", "4096"] {
        let cfg = config_from(&[
            ("sampler.kind", "rff".into()),
            ("sampler.num_negatives", "100".into()),
            ("sampler.dim", d.into()),
            ("sampler.T", "0.5".into()),
            ("train.steps", steps.to_string()),
            ("train.eval_every", eval_every.to_string()),
            ("train.eval_batches", "4".into()),
            ("train.lr", "0.5".into()),
            ("data.train_size", "120000".into()),
            ("data.valid_size", "10000".into()),
        ])?;
        let r = train_once(&runtime, "ptb", &format!("D={d}"), cfg)?;
        runs.push((format!("D={d}"), r));
    }
    // Reference: EXP (sampling from the exact softmax = D → ∞ limit).
    let cfg = config_from(&[
        ("sampler.kind", "exact".into()),
        ("sampler.num_negatives", "100".into()),
        ("train.steps", steps.to_string()),
        ("train.eval_every", eval_every.to_string()),
        ("train.eval_batches", "4".into()),
        ("train.lr", "0.5".into()),
        ("data.train_size", "120000".into()),
        ("data.valid_size", "10000".into()),
    ])?;
    let r = train_once(&runtime, "ptb", "exp", cfg)?;
    runs.push(("EXP (D→∞)".into(), r));

    println!(
        "\n{}",
        curves_table(
            "Figure 2 — validation perplexity vs step, varying D \
             (PTB-scale, m=100, T=0.5)",
            &runs
        )
        .render()
    );
    println!("shape check: larger D → lower curve, approaching EXP.");
    Ok(())
}
