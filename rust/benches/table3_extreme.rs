//! Table 3 reproduction: PREC@{1,3,5} of EXP / UNIFORM / QUADRATIC / RFF
//! on extreme-classification datasets at AmazonCat-13K, Delicious-200K and
//! WikiLSHTC shapes (planted-embedding stand-ins, DESIGN.md §2).
//!
//! Paper shape: EXP best or tied; RFF within a point or two of EXP and
//! ≥ QUADRATIC on AmazonCat/Delicious; UNIFORM clearly worst everywhere.
//!
//! `RFSM_QUICK=1` runs AmazonCat only. Run:
//! `cargo bench --bench table3_extreme`

use anyhow::Result;
use rfsoftmax::benchkit::bench_header;
use rfsoftmax::coordinator::harness::{bench_steps, corpus_config};
use rfsoftmax::coordinator::{Trainer, TrainerBuilder};
use rfsoftmax::runtime::Runtime;
use rfsoftmax::tables::Table;

/// (prefix, train examples, paper rows [method, P@1, P@3, P@5]).
const DATASETS: &[(&str, usize, &[(&str, f64, f64, f64)])] = &[
    (
        "xc_amazon",
        20_000,
        &[
            ("EXP", 0.87, 0.76, 0.62),
            ("UNIFORM", 0.83, 0.69, 0.55),
            ("QUADRATIC", 0.84, 0.74, 0.60),
            ("RFF", 0.87, 0.75, 0.61),
        ],
    ),
    (
        "xc_delicious",
        12_000,
        &[
            ("EXP", 0.42, 0.38, 0.37),
            ("UNIFORM", 0.36, 0.34, 0.32),
            ("QUADRATIC", 0.40, 0.36, 0.34),
            ("RFF", 0.41, 0.37, 0.36),
        ],
    ),
    (
        "xc_wiki",
        12_000,
        &[
            ("EXP", 0.58, 0.37, 0.29),
            ("UNIFORM", 0.47, 0.29, 0.22),
            ("QUADRATIC", 0.57, 0.37, 0.28),
            ("RFF", 0.56, 0.35, 0.26),
        ],
    ),
];

fn kind_of(label: &str) -> &'static str {
    match label {
        "EXP" => "exact",
        "UNIFORM" => "uniform",
        "QUADRATIC" => "quadratic",
        "RFF" => "rff",
        _ => unreachable!(),
    }
}

fn main() -> Result<()> {
    bench_header("T3", "extreme classification PREC@k (paper Table 3)");
    let runtime = Runtime::native();
    let base_steps = bench_steps(2500);
    let quick = std::env::var("RFSM_QUICK").is_ok();

    for (prefix, train_size, paper_rows) in DATASETS {
        if quick && *prefix != "xc_amazon" {
            println!("(RFSM_QUICK: skipping {prefix})");
            continue;
        }
        // Large-n datasets get fewer steps (every method's per-step cost
        // grows with n; the ordering shows well before convergence).
        let steps =
            if *prefix == "xc_amazon" { base_steps } else { base_steps / 2 };
        println!("\n-- {prefix} --");
        let mut table = Table::new(
            &format!("Table 3 — {prefix} (steps={steps})"),
            &["Method", "P@1", "P@3", "P@5", "paper P@1/3/5", "wall (s)"],
        );
        for (label, p1p, p3p, p5p) in *paper_rows {
            let cfg = corpus_config(
                prefix,
                &[
                    ("sampler.kind", kind_of(label).into()),
                    ("sampler.num_negatives", "100".into()),
                    ("sampler.dim", "256".into()),
                    ("sampler.T", "0.5".into()),
                    ("train.steps", steps.to_string()),
                    ("train.eval_every", steps.to_string()),
                    ("train.eval_batches", "8".into()),
                    ("train.lr", "1.0".into()),
                    ("data.train_size", train_size.to_string()),
                    ("data.valid_size", "1024".into()),
                    ("data.noise", "0.15".into()),
                ],
            )?;
            let t0 = std::time::Instant::now();
            let mut trainer =
                TrainerBuilder::new(&runtime, prefix, cfg).build()?;
            trainer.run()?;
            let (p1, p3, p5) = match &mut trainer {
                Trainer::Xc(t) => t.final_precisions()?,
                _ => unreachable!("xc prefix"),
            };
            println!("  {label:<10} P@1 {p1:.3} P@3 {p3:.3} P@5 {p5:.3}");
            table.row(&[
                label.to_string(),
                format!("{p1:.2}"),
                format!("{p3:.2}"),
                format!("{p5:.2}"),
                format!("{p1p:.2}/{p3p:.2}/{p5p:.2}"),
                format!("{:.0}", t0.elapsed().as_secs_f64()),
            ]);
        }
        println!("\n{}", table.render());
    }
    println!(
        "shape check: UNIFORM worst on every dataset; RFF within a couple \
         of points of EXP; RFF ≥ QUADRATIC on amazon/delicious."
    );
    Ok(())
}
