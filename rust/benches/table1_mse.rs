//! Table 1 reproduction: MSE of approximating the exponential kernel
//! `exp(τ·hᵀc)` on USPS-like normalized data (d = 256).
//!
//! Paper rows: Quadratic D=256² (2.8e-3), RFF D=100/1000/256²
//! (2.6e-3 / 2.7e-4 / 5.5e-6), Random Maclaurin D=256² (8.8e-2).
//! The *shape* to reproduce: RFF ≪ Quadratic at equal D; RFF MSE ∝ 1/D;
//! Maclaurin worst by orders of magnitude at practical D.
//!
//! Run: `cargo bench --bench table1_mse`

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::data::usps_like::{pairs, UspsLikeParams};
use rfsoftmax::featmap::{
    exp_kernel, FeatureMap, MaclaurinMap, OrfMap, QuadraticMap, RffMap,
    SorfMap,
};
use rfsoftmax::rng::Rng;
use rfsoftmax::tables::{fmt_sci, Table};

fn mse_for(
    map: &dyn FeatureMap,
    scale: f64,
    tau: f32,
    ps: &[(Vec<f32>, Vec<f32>)],
) -> f64 {
    let mut se = 0.0;
    for (x, y) in ps {
        let e = exp_kernel(tau, x, y) - scale * map.approx_kernel(x, y);
        se += e * e;
    }
    se / ps.len() as f64
}

fn main() {
    bench_header("T1", "kernel-approximation MSE (paper Table 1)");
    let d = 256;
    let tau = 1.0f32;
    let n_pairs: usize = std::env::var("RFSM_T1_PAIRS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let mut rng = Rng::seeded(1);
    let ps = pairs(&UspsLikeParams::default(), 512, n_pairs, &mut rng);
    let scale = (tau as f64).exp(); // RFF estimates e^{-ν}·exp-kernel

    let mut t = Table::new(
        &format!("Table 1 — MSE approximating exp(τhᵀc), τ={tau}, d={d}, {n_pairs} pairs"),
        &["Method", "D", "MSE", "paper"],
    );

    let quad = QuadraticMap::fit(d, &ps, |x, y| exp_kernel(tau, x, y));
    t.row(&[
        "Quadratic (fit α,β)".into(),
        format!("{}", d * d),
        fmt_sci(mse_for(&quad, 1.0, tau, &ps)),
        "2.8e-3".into(),
    ]);
    let quad_fixed = QuadraticMap::new(d, 100.0, 1.0);
    t.row(&[
        "Quadratic (α=100)".into(),
        format!("{}", d * d),
        fmt_sci(mse_for(&quad_fixed, 1.0, tau, &ps)),
        "(larger)".into(),
    ]);

    for (dd, paper) in [(100usize, "2.6e-3"), (1000, "2.7e-4"), (d * d, "5.5e-6")] {
        let m = RffMap::new(d, dd, tau, &mut rng);
        t.row(&[
            "Random Fourier".into(),
            format!("{dd}"),
            fmt_sci(mse_for(&m, scale, tau, &ps)),
            paper.into(),
        ]);
    }

    // Extensions beyond the paper's table: ORF/SORF at D=1000.
    let orf = OrfMap::new(d, 1000, tau, &mut rng);
    t.row(&[
        "Orthogonal RF (ext)".into(),
        "1000".into(),
        fmt_sci(mse_for(&orf, scale, tau, &ps)),
        "-".into(),
    ]);
    let sorf = SorfMap::new(d, 1000, tau, &mut rng);
    t.row(&[
        "Structured ORF (ext)".into(),
        "1000".into(),
        fmt_sci(mse_for(&sorf, scale, tau, &ps)),
        "-".into(),
    ]);

    let mac = MaclaurinMap::new(d, d * d, tau, &mut rng);
    t.row(&[
        "Random Maclaurin".into(),
        format!("{}", d * d),
        fmt_sci(mse_for(&mac, 1.0, tau, &ps)),
        "8.8e-2".into(),
    ]);

    println!("{}", t.render());
    println!(
        "shape check: RFF(1000) < RFF(100); RFF(100) ≤ Quadratic(fit); \
         Maclaurin worst."
    );
}
