//! Serving-subsystem benchmark: closed-loop throughput and latency of the
//! epoch-versioned `SamplerServer` + request micro-batcher, under live
//! writer churn — the perf trajectory of the serving path, alongside
//! `perf_hotpath`'s training-path lines.
//!
//! Covers `{rff, rff-sharded} × {1, 4, 8}` reader threads × `{inproc,
//! uds, tcp}` transports (the wire cells run a mixed `8:1:1`
//! sample:prob:topk request stream over the real protocol) and emits
//! one `BENCH {json}` record per cell with qps, p50/p99 latency (µs),
//! mean coalesced batch size, per-kind request counts, published
//! epochs, swap-stall count, and frame encode/decode overhead. A final
//! tcp section sweeps the wire v3 wave size (1 vs 8 vs 32) so the
//! per-request header amortization (`req_headers_per_request`) rides
//! the trajectory. Every record also carries the live-telemetry
//! `stages` breakdown (per-stage count + p50/p99) and the attributed
//! `telemetry_overhead_pct`, which CI budgets at ≤ 2% via
//! `bench-check --require-telemetry-overhead 2`.
//!
//! Run: `cargo bench --bench perf_serving`

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::featmap::RffMap;
use rfsoftmax::linalg::{Matrix, QuantizeKind};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{RffSampler, Sampler, ShardedKernelSampler};
use rfsoftmax::serving::{
    run_closed_loop, BatcherOptions, ChurnSpec, LoadSpec, RequestMix,
    TransportMode,
};
use std::time::Duration;

fn main() {
    bench_header("SERVE", "serving subsystem closed-loop load (L3.5 + L4)");
    let n = 20_000;
    let d = 64;
    let num_freqs = 128;
    let m = 20;
    let mut rng = Rng::seeded(1);
    let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();

    let samplers: Vec<(&str, Box<dyn Sampler>)> = vec![
        (
            "rff",
            Box::new(RffSampler::new(&classes, num_freqs, 4.0, &mut rng)),
        ),
        (
            "rff-sharded",
            Box::new(ShardedKernelSampler::with_map(
                &classes,
                RffMap::new(d, num_freqs, 4.0, &mut Rng::seeded(2)),
                8,
                "rff-sharded",
            )),
        ),
    ];

    // (transport, mix, total requests across readers): inproc keeps the
    // PR-2 pure-sample line comparable across PRs; uds and tcp exercise
    // the wire with a mixed request stream.
    let transports = [
        (TransportMode::Inproc, RequestMix { sample: 1, prob: 0, topk: 0 }, 4000),
        (TransportMode::Uds, RequestMix { sample: 8, prob: 1, topk: 1 }, 2000),
        (TransportMode::Tcp, RequestMix { sample: 8, prob: 1, topk: 1 }, 2000),
    ];

    for (tmode, mix, total_requests) in &transports {
        println!(
            "\n# closed loop: transport={} mix={} n={n} d={d} D={num_freqs} \
             m={m}, writer swaps every 32 updates",
            tmode.name(),
            mix.label(),
        );
        for (label, sampler) in &samplers {
            for &readers in &[1usize, 4, 8] {
                let spec = LoadSpec {
                    readers,
                    // Keep total work comparable across thread counts.
                    requests_per_reader: total_requests / readers,
                    m,
                    top_k: 10,
                    dim: d,
                    seed: 7,
                    // Natural batching (no artificial wait): with
                    // closed-loop readers, any positive max_wait would
                    // dominate the measured latency instead of the
                    // sampler.
                    batcher: BatcherOptions {
                        max_batch: 32,
                        max_wait: Duration::ZERO,
                    },
                    updates_per_swap: 32,
                    swap_pause: Duration::from_micros(200),
                    transport: *tmode,
                    mix: *mix,
                    churn: None,
                    wave: 1,
                    listen: "127.0.0.1:0".into(),
                    quantize: QuantizeKind::None,
                    hold: Duration::ZERO,
                    ..LoadSpec::default()
                };
                match run_closed_loop(sampler.as_ref(), &spec) {
                    Ok(report) => {
                        println!("{}", report.render());
                        println!("BENCH {}", report.to_json());
                    }
                    Err(e) => println!("{label}: SKIP ({e})"),
                }
            }
        }
    }

    // Churn cells: live class-universe mutation (3 adds : 1 retire, 200
    // ops of 8 classes) under the mixed closed loop — the BENCH records
    // carry mutation-latency percentiles and post-churn qps so the
    // trajectory tracks churn cost from this PR onward. The uds cell
    // drives the mutations as ADD_CLASSES/RETIRE_CLASSES admin frames.
    let churn = ChurnSpec { adds: 3, retires: 1, ops: 200, batch: 8 };
    for (tmode, mix, total_requests) in &transports {
        println!(
            "\n# churn closed loop: transport={} mix={} churn={} n={n}",
            tmode.name(),
            mix.label(),
            churn.label(),
        );
        for (label, sampler) in &samplers {
            let spec = LoadSpec {
                readers: 4,
                requests_per_reader: total_requests / 4,
                m,
                top_k: 10,
                dim: d,
                seed: 7,
                batcher: BatcherOptions {
                    max_batch: 32,
                    max_wait: Duration::ZERO,
                },
                updates_per_swap: 32,
                swap_pause: Duration::from_micros(200),
                transport: *tmode,
                mix: *mix,
                churn: Some(churn),
                wave: 1,
                listen: "127.0.0.1:0".into(),
                quantize: QuantizeKind::None,
                hold: Duration::ZERO,
                ..LoadSpec::default()
            };
            match run_closed_loop(sampler.as_ref(), &spec) {
                Ok(report) => {
                    println!("{}", report.render());
                    println!("BENCH {}", report.to_json());
                }
                Err(e) => println!("{label}: SKIP ({e})"),
            }
        }
    }

    // Wave-size sweep over tcp: the per-request frame-header overhead
    // (req/resp_headers_per_request in the BENCH records) drops toward
    // 1/wave, the observable the batched-wave frames exist for.
    println!("\n# tcp wave sweep: mix=8:1:1 readers=4 n={n}");
    for &wave in &[1usize, 8, 32] {
        let sampler = &samplers[1].1; // rff-sharded
        let spec = LoadSpec {
            readers: 4,
            requests_per_reader: 512,
            m,
            top_k: 10,
            dim: d,
            seed: 7,
            batcher: BatcherOptions {
                // Batch bound ≥ wave so one wave coalesces whole.
                max_batch: 32,
                max_wait: Duration::ZERO,
            },
            updates_per_swap: 32,
            swap_pause: Duration::from_micros(200),
            transport: TransportMode::Tcp,
            mix: RequestMix { sample: 8, prob: 1, topk: 1 },
            churn: None,
            wave,
            listen: "127.0.0.1:0".into(),
            quantize: QuantizeKind::None,
            hold: Duration::ZERO,
            ..LoadSpec::default()
        };
        match run_closed_loop(sampler.as_ref(), &spec) {
            Ok(report) => {
                println!("{}", report.render());
                println!("BENCH {}", report.to_json());
            }
            Err(e) => println!("wave={wave}: SKIP ({e})"),
        }
    }
}
