//! Hot-path microbenchmarks for the §Perf optimization loop (DESIGN.md
//! §7): the L3 operations that sit on every training step.
//!
//! * kernel-tree `sample` / `update` at several (n, D),
//! * feature maps: classic RFF vs ORF vs SORF (O(Dd) vs O(D log d)),
//! * SIMD `matmul_nt` microkernel vs the scalar reference (the ISSUE 6
//!   dispatch win, gated in CI via `bench-check --require-simd-speedup`),
//! * quantized sampler embeddings: draw throughput + memory at
//!   `none`/`f16`/`i8` storage,
//! * sampled-softmax loss oracle,
//! * warm restart: durable-snapshot restore vs cold rebuild + churn
//!   replay (the ISSUE 10 durability win, gated in CI via
//!   `bench-check --require-restore-speedup`),
//! * batch negative-draw path as the coordinator runs it,
//! * batch-vs-scalar `sample_batch` throughput (emits `BENCH {json}`
//!   lines so the perf trajectory is machine-readable).
//!
//! Run: `cargo bench --bench perf_hotpath`
//!
//! `--smoke` (CI bench-smoke job: `cargo bench --bench perf_hotpath --
//! --smoke`) shrinks every axis — problem sizes, warmup, budget — so the
//! full harness executes end to end in seconds and still emits every
//! `BENCH {json}` record kind; the numbers are not comparable to full
//! runs (the record gains `"smoke": true` so the trajectory can filter).

use rfsoftmax::benchkit::{bench_header, black_box, Bencher};
use rfsoftmax::config::FeatureMapKind;
use rfsoftmax::featmap::{FeatureMap, OrfMap, RffMap, SorfMap};
use rfsoftmax::json::Json;
use rfsoftmax::linalg::{simd, unit_vector, Matrix, QuantizeKind};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{KernelTree, RffSampler, Sampler};
use rfsoftmax::softmax::sampled_softmax_loss;
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench_header(
        "PERF",
        if smoke {
            "L3 hot-path microbenchmarks (SMOKE: tiny sizes, seconds-scale)"
        } else {
            "L3 hot-path microbenchmarks"
        },
    );
    let b = if smoke {
        Bencher {
            warmup: Duration::from_millis(5),
            budget: Duration::from_millis(40),
            samples: 3,
        }
    } else {
        Bencher {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(600),
            samples: 12,
        }
    };

    // ------------------------------------------------------------------
    // Feature maps (φ computation): RFF vs ORF vs SORF.
    // ------------------------------------------------------------------
    println!("\n# feature maps (d=128)");
    let mut rng = Rng::seeded(1);
    let d = 128;
    let u = unit_vector(&mut rng, d);
    let map_sizes: &[usize] =
        if smoke { &[256] } else { &[256, 1024, 4096] };
    for &nf in map_sizes {
        let rff = RffMap::new(d, nf, 4.0, &mut rng);
        let orf = OrfMap::new(d, nf, 4.0, &mut rng);
        let sorf = SorfMap::new(d, nf, 4.0, &mut rng);
        let mut out = vec![0.0f32; rff.output_dim()];
        println!("{}", b.run(&format!("rff_map D={nf}"), || {
            rff.map_into(&u, &mut out);
            black_box(out[0])
        }).report());
        println!("{}", b.run(&format!("orf_map D={nf}"), || {
            orf.map_into(&u, &mut out);
            black_box(out[0])
        }).report());
        println!("{}", b.run(&format!("sorf_map D={nf}"), || {
            sorf.map_into(&u, &mut out);
            black_box(out[0])
        }).report());
    }

    // ------------------------------------------------------------------
    // SIMD gemm microkernel A/B: the runtime-dispatched matmul_nt vs the
    // always-compiled scalar reference on the same buffers. The BENCH
    // record carries the resolved tier so forced-scalar CI lanes
    // (speedup ≈ 1) are distinguishable from real vectorized runs.
    // ------------------------------------------------------------------
    println!("\n# simd matmul_nt microkernel (dispatch tier: {})", simd::tier_name());
    {
        let (r, k, cols) = if smoke { (64, 256, 256) } else { (256, 1000, 256) };
        let mut rng = Rng::seeded(12);
        let mut a = vec![0.0f32; r * k];
        let mut bt = vec![0.0f32; cols * k];
        rng.fill_gaussian_f32(&mut a);
        rng.fill_gaussian_f32(&mut bt);
        let mut out = vec![0.0f32; r * cols];
        let s_simd = b.run(&format!("matmul_nt {r}x{k} x {cols}x{k}T (simd)"), || {
            simd::matmul_nt_into(&a, r, k, &bt, cols, &mut out);
            black_box(out[0])
        });
        let s_scalar = b.run(&format!("matmul_nt {r}x{k} x {cols}x{k}T (scalar)"), || {
            simd::scalar::matmul_nt_into(&a, r, k, &bt, cols, &mut out);
            black_box(out[0])
        });
        println!("{}", s_simd.report());
        println!("{}", s_scalar.report());
        let simd_per_sec = 1.0 / s_simd.mean();
        let scalar_per_sec = 1.0 / s_scalar.mean();
        let record = Json::obj(vec![
            ("bench", Json::from("simd_matmul_nt")),
            ("r", Json::from(r)),
            ("k", Json::from(k)),
            ("d", Json::from(cols)),
            ("simd", Json::from(simd::tier_name())),
            ("simd_per_sec", Json::from(simd_per_sec)),
            ("scalar_per_sec", Json::from(scalar_per_sec)),
            ("speedup", Json::from(simd_per_sec / scalar_per_sec)),
            ("smoke", Json::from(smoke)),
        ]);
        println!("BENCH {record}");
    }

    // ------------------------------------------------------------------
    // Kernel tree: sample + update at several scales.
    // ------------------------------------------------------------------
    println!("\n# kernel tree (query dim = 2D feature coords)");
    let tree_cells: &[(usize, usize)] = if smoke {
        &[(2_000, 128)]
    } else {
        &[(10_000, 128), (10_000, 512), (100_000, 128)]
    };
    for &(n, nf) in tree_cells {
        let dim = 2 * nf;
        let mut rng = Rng::seeded(2);
        let mut tree = KernelTree::new(n, dim, 1e-8);
        let mut phi = vec![0.0f32; dim];
        for i in 0..n {
            rng.fill_gaussian_f32(&mut phi);
            tree.add_leaf(i, &phi);
        }
        let mut z = vec![0.0f32; dim];
        rng.fill_gaussian_f32(&mut z);
        let mut sample_rng = Rng::seeded(3);
        println!("{}", b.run(&format!("tree_sample n={n} D'={dim}"), || {
            black_box(tree.sample(&z, &mut sample_rng))
        }).report());
        let mut delta = vec![0.0f32; dim];
        rng.fill_gaussian_f32(&mut delta);
        let mut i = 0usize;
        println!("{}", b.run(&format!("tree_update n={n} D'={dim}"), || {
            i = (i + 1) % n;
            tree.update_leaf(i, &delta);
            black_box(i)
        }).report());
    }

    // ------------------------------------------------------------------
    // Full coordinator negative-draw path (φ(h) + m tree draws).
    // ------------------------------------------------------------------
    println!("\n# negative-draw path (n=10k, d=64, m=100)");
    let mut rng = Rng::seeded(4);
    let draw_n = if smoke { 2_000 } else { 10_000 };
    let classes = Matrix::randn(&mut rng, draw_n, 64).l2_normalized_rows();
    let draw_sizes: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    for &nf in draw_sizes {
        let sampler = RffSampler::new(&classes, nf, 4.0, &mut rng);
        let h = unit_vector(&mut rng, 64);
        let mut draw_rng = Rng::seeded(5);
        println!("{}", b.run(&format!("rff_draw m=100 D={nf}"), || {
            black_box(sampler.sample(&h, 100, &mut draw_rng))
        }).report());
    }

    // ------------------------------------------------------------------
    // Quantized sampler embeddings: draw throughput and resident memory
    // at each storage precision. The f32 cell doubles as the quantized
    // cells' baseline under `bench-check --baseline`.
    // ------------------------------------------------------------------
    {
        let qn = if smoke { 2_000 } else { 20_000 };
        let d = 64;
        let m = 20;
        println!("\n# quantized sampler embeddings (n={qn}, d={d}, D=128, m={m})");
        let mut rng = Rng::seeded(13);
        let classes = Matrix::randn(&mut rng, qn, d).l2_normalized_rows();
        for qk in [QuantizeKind::None, QuantizeKind::F16, QuantizeKind::I8] {
            let sampler = RffSampler::with_kind_opts(
                &classes,
                128,
                4.0,
                FeatureMapKind::Rff,
                &mut Rng::seeded(14),
                0,
                qk,
            );
            let h = unit_vector(&mut rng, d);
            let mut draw_rng = Rng::seeded(15);
            let s = b.run(&format!("rff_draw m={m} quantize={}", qk.name()), || {
                black_box(sampler.sample(&h, m, &mut draw_rng))
            });
            println!("{}", s.report());
            let record = Json::obj(vec![
                ("bench", Json::from("quantized_sampler")),
                ("n", Json::from(qn)),
                ("d", Json::from(d)),
                ("m", Json::from(m)),
                ("quantize", Json::from(qk.name())),
                ("simd", Json::from(simd::tier_name())),
                ("draws_per_sec", Json::from(m as f64 / s.mean())),
                ("memory_bytes", Json::from(sampler.memory_bytes())),
                ("smoke", Json::from(smoke)),
            ]);
            println!("BENCH {record}");
        }
    }

    // ------------------------------------------------------------------
    // Warm restart: durable-snapshot restore vs the cold crash-recovery
    // path. Cold recovery of a churned sampler means rebuilding from
    // the seed embeddings and replaying the whole add/retire history —
    // n feature-map evaluations plus one O(D·log n) tree walk per op.
    // Warm restore swaps the captured state into a one-row skeleton
    // wholesale, O(state). `restore_speedup` compares exactly those two
    // (the serving stack's `apply_restore` path, state already fetched
    // and decoded — replica bootstrap streams and decodes the bytes
    // while the donor keeps serving); the one-time codec decode cost
    // (checksum + parse) is measured alongside as `decode_ms` so the
    // full from-bytes wall time is `decode_ms + restore_ms`. CI gates
    // the speedup via `bench-check --require-restore-speedup`.
    // ------------------------------------------------------------------
    {
        let (wn, wd, wnf, wshards) =
            if smoke { (2_000, 64, 128, 4) } else { (20_000, 64, 128, 8) };
        let batch = 8usize;
        let rounds = wn / batch;
        println!(
            "\n# warm restart: snapshot restore vs cold rebuild + churn \
             replay (n={wn}, d={wd}, D={wnf}, {} replayed ops)",
            2 * rounds
        );
        let mut rng = Rng::seeded(16);
        let classes = Matrix::randn(&mut rng, wn, wd).l2_normalized_rows();
        // Churn history: each round grows `batch` fresh classes and
        // retires `batch` seed classes, pre-generated so every cold
        // replay reproduces the same final universe the snapshot holds
        // (live count stays n; the slot table doubles with holes).
        let adds: Vec<Matrix> = (0..rounds)
            .map(|_| Matrix::randn(&mut rng, batch, wd).l2_normalized_rows())
            .collect();
        let retires: Vec<Vec<u32>> = (0..rounds)
            .map(|r| (0..batch).map(|j| (r * batch + j) as u32).collect())
            .collect();
        let fresh_map = || RffMap::new(wd, wnf, 4.0, &mut Rng::seeded(17));
        let rebuild = || {
            let mut s = rfsoftmax::sampler::ShardedKernelSampler::with_map(
                &classes,
                fresh_map(),
                wshards,
                "rff-sharded",
            );
            for (a, r) in adds.iter().zip(&retires) {
                s.add_classes(a).expect("replay add");
                s.retire_classes(r).expect("replay retire");
            }
            s
        };
        let snap = rfsoftmax::snapshot::Snapshot {
            epoch: rounds as u64,
            state: rebuild().snapshot_state().expect("sharded snapshots"),
        };
        let bytes = rfsoftmax::snapshot::encode(&snap);
        let skeleton_row = Matrix::zeros(1, wd);
        let s_cold = b.run("cold_rebuild + replay", || {
            black_box(rebuild().live_classes())
        });
        let s_restore = b.run("warm_restore (skeleton + state swap)", || {
            let mut skel = rfsoftmax::sampler::ShardedKernelSampler::with_map(
                &skeleton_row,
                fresh_map(),
                wshards,
                "rff-sharded",
            );
            skel.restore_state(&snap.state).expect("restore");
            black_box(skel.live_classes())
        });
        let s_decode = b.run("snapshot_decode (checksum + parse)", || {
            black_box(rfsoftmax::snapshot::decode(&bytes).expect("decode").epoch)
        });
        println!("{}", s_cold.report());
        println!("{}", s_restore.report());
        println!("{}", s_decode.report());
        let record = Json::obj(vec![
            ("bench", Json::from("warm_restart")),
            ("n", Json::from(wn)),
            ("d", Json::from(wd)),
            ("shards", Json::from(wshards)),
            ("replayed_ops", Json::from(2 * rounds)),
            ("snapshot_bytes", Json::from(bytes.len())),
            ("cold_ms", Json::from(s_cold.mean() * 1e3)),
            ("restore_ms", Json::from(s_restore.mean() * 1e3)),
            ("decode_ms", Json::from(s_decode.mean() * 1e3)),
            ("restore_per_sec", Json::from(1.0 / s_restore.mean())),
            ("restore_speedup", Json::from(s_cold.mean() / s_restore.mean())),
            ("simd", Json::from(simd::tier_name())),
            ("smoke", Json::from(smoke)),
        ]);
        println!("BENCH {record}");
    }

    // §Perf A/B: memoized batch walk vs m independent walks on the raw
    // tree (the optimization's before/after, recorded in EXPERIMENTS.md).
    println!("\n# tree batch-draw memoization A/B (n=10k, D'=2048, m=100)");
    {
        let dim = if smoke { 512 } else { 2048 };
        let n = if smoke { 2_000 } else { 10_000 };
        let mut rng = Rng::seeded(9);
        let mut tree = KernelTree::new(n, dim, 1e-8);
        let mut phi = vec![0.0f32; dim];
        for i in 0..n {
            rng.fill_gaussian_f32(&mut phi);
            tree.add_leaf(i, &phi);
        }
        let mut z = vec![0.0f32; dim];
        rng.fill_gaussian_f32(&mut z);
        let mut r1 = Rng::seeded(10);
        println!("{}", b.run("sample_many m=100 (memo, after)", || {
            black_box(tree.sample_many(&z, 100, &mut r1))
        }).report());
        let mut r2 = Rng::seeded(10);
        println!("{}", b.run("sample_many m=100 (nomemo, before)", || {
            black_box(tree.sample_many_nomemo(&z, 100, &mut r2))
        }).report());
    }

    // ------------------------------------------------------------------
    // Batch-vs-scalar sampling throughput (ISSUE 1 acceptance gate:
    // batch-256 ≥ 2× the scalar loop at n = 10⁵). The scalar loop is the
    // pre-refactor coordinator shape — one sample_negatives call per
    // example, re-mapping φ(h) every time; sample_batch maps the whole
    // batch in one gemm and fans the walks out across threads.
    // ------------------------------------------------------------------
    println!("\n# batch-vs-scalar sampling (d=64, D=128, m=20 negatives/example)");
    let bvs_sizes: &[usize] =
        if smoke { &[2_000] } else { &[10_000, 100_000] };
    for &n in bvs_sizes {
        let mut rng = Rng::seeded(7);
        let d = 64;
        let m = 20;
        let classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
        let sampler = RffSampler::new(&classes, 128, 4.0, &mut rng);
        let batch_sizes: &[usize] =
            if smoke { &[1, 32] } else { &[1, 32, 256] };
        for &bsz in batch_sizes {
            let h = Matrix::randn(&mut rng, bsz, d).l2_normalized_rows();
            let targets: Vec<u32> = (0..bsz).map(|b| (b % n) as u32).collect();
            let mut r1 = Rng::seeded(11);
            let s_batch = b.run(&format!("sample_batch n={n} bsz={bsz}"), || {
                black_box(sampler.sample_batch(&h, &targets, m, &mut r1))
            });
            let mut r2 = Rng::seeded(11);
            let s_scalar = b.run(&format!("scalar_loop  n={n} bsz={bsz}"), || {
                let mut total = 0usize;
                for bi in 0..bsz {
                    let draw = sampler.sample_negatives(
                        h.row(bi),
                        targets[bi] as usize,
                        m,
                        &mut r2,
                    );
                    total += draw.len();
                }
                black_box(total)
            });
            println!("{}", s_batch.report());
            println!("{}", s_scalar.report());
            let batch_sps = (bsz * m) as f64 / s_batch.mean();
            let scalar_sps = (bsz * m) as f64 / s_scalar.mean();
            let record = Json::obj(vec![
                ("bench", Json::from("batch_vs_scalar_sampling")),
                ("n", Json::from(n)),
                ("batch", Json::from(bsz)),
                ("m", Json::from(m)),
                ("batch_samples_per_sec", Json::from(batch_sps)),
                ("scalar_samples_per_sec", Json::from(scalar_sps)),
                ("speedup", Json::from(batch_sps / scalar_sps)),
                ("simd", Json::from(simd::tier_name())),
                ("smoke", Json::from(smoke)),
            ]);
            println!("BENCH {record}");
        }
    }

    // ------------------------------------------------------------------
    // Loss oracle (rust-side, used by the bias harness + table2).
    // ------------------------------------------------------------------
    println!("\n# sampled-softmax loss oracle");
    let mut rng = Rng::seeded(6);
    let loss_sizes: &[usize] =
        if smoke { &[10, 100] } else { &[10, 100, 1000] };
    for &m in loss_sizes {
        let negs: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        let q: Vec<f64> = (0..m).map(|_| rng.f64_open()).collect();
        println!("{}", b.run(&format!("loss m={m}"), || {
            black_box(sampled_softmax_loss(0.5, &negs, &q).loss)
        }).report());
    }
}
