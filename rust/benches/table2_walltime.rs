//! Table 2 reproduction: wall time to compute the sampled-softmax loss
//! for one batch (batch = 10, m = 10, d = 64) under each model-dependent
//! sampling method, at n = 10,000 and n = 500,000.
//!
//! Paper rows (ms): n=10k — EXP 1.4, QUADRATIC 6.5, RFF(50/200/500/1000)
//! 0.5/0.6/1.2/1.4; n=500k — EXP 32.3, QUADRATIC 8.2, RFF 1.6/1.7/2.0/2.4.
//! Shape to reproduce: EXP grows linearly in n and loses badly at 500k;
//! RFF stays ~flat in n (log n) and scales mildly with D; QUADRATIC sits
//! well above RFF at the same n (its D is d² = 4096).
//!
//! `RFSM_QUICK=1` limits to n = 10,000 (the 500k tree builds take ~1 min
//! on this single-core box and are reported separately as build time).
//!
//! Run: `cargo bench --bench table2_walltime`

use rfsoftmax::benchkit::{bench_header, black_box, Bencher};
use rfsoftmax::linalg::{unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{
    BucketKernelSampler, ExactSoftmaxSampler, RffSampler, Sampler,
};
use rfsoftmax::softmax::sampled_softmax_loss;
use rfsoftmax::tables::Table;
use std::time::Duration;

const BATCH: usize = 10;
const M: usize = 10;
const D_EMB: usize = 64;
const TAU: f32 = 4.0;

/// One "compute sampled softmax loss" unit, as the paper times it:
/// draw m negatives for the batch query, adjust, evaluate the loss for
/// every example in the batch.
fn loss_once(
    sampler: &dyn Sampler,
    queries: &[Vec<f32>],
    classes: &Matrix,
    rng: &mut Rng,
) -> f64 {
    let q0 = &queries[0];
    let draw = sampler.sample(q0, M, rng);
    let mut acc = 0.0;
    for h in queries {
        let o_t = (TAU * rfsoftmax::linalg::dot(h, classes.row(0))) as f64;
        let negs: Vec<f64> = draw
            .ids
            .iter()
            .map(|&i| {
                (TAU * rfsoftmax::linalg::dot(h, classes.row(i as usize)))
                    as f64
            })
            .collect();
        acc += sampled_softmax_loss(o_t, &negs, &draw.probs).loss;
    }
    acc
}

fn bench_method(
    b: &Bencher,
    name: &str,
    sampler: &dyn Sampler,
    classes: &Matrix,
    build_secs: f64,
    table: &mut Table,
    paper: &str,
) {
    let mut rng = Rng::seeded(77);
    let queries: Vec<Vec<f32>> =
        (0..BATCH).map(|_| unit_vector(&mut rng, D_EMB)).collect();
    let mut sample_rng = Rng::seeded(78);
    let s = b.run(name, || {
        black_box(loss_once(sampler, &queries, classes, &mut sample_rng))
    });
    println!("  {}", s.report());
    table.row(&[
        name.to_string(),
        format!("{:.2} ms", s.mean() * 1e3),
        paper.to_string(),
        format!("{build_secs:.1} s"),
    ]);
}

fn run_for_n(n: usize, paper: &[(&str, &str)]) {
    println!("\n-- n = {n} --");
    let mut rng = Rng::seeded(7);
    let classes = Matrix::randn(&mut rng, n, D_EMB).l2_normalized_rows();
    let b = Bencher {
        warmup: Duration::from_millis(100),
        budget: Duration::from_millis(800),
        samples: 10,
    };
    let mut table = Table::new(
        &format!("Table 2 — sampled-softmax loss wall time, n={n} (batch=10, m=10, d=64)"),
        &["Method", "wall", "paper", "build"],
    );

    // EXP: exact softmax sampling, O(dn).
    let t0 = std::time::Instant::now();
    let exact = ExactSoftmaxSampler::new(&classes, TAU);
    bench_method(&b, "Exp", &exact, &classes, t0.elapsed().as_secs_f64(), &mut table, paper[0].1);

    // QUADRATIC: kernel tree with D = d²+1 (bucketed at large n).
    let t0 = std::time::Instant::now();
    if n <= 100_000 {
        let quad = rfsoftmax::sampler::QuadraticSampler::new(&classes, 100.0, 1.0);
        bench_method(&b, "Quadratic", &quad, &classes, t0.elapsed().as_secs_f64(), &mut table, paper[1].1);
    } else {
        let map = rfsoftmax::featmap::QuadraticMap::new(D_EMB, 100.0, 1.0);
        let quad = BucketKernelSampler::with_map(&classes, map, 1024, "quadratic");
        bench_method(&b, "Quadratic (bucketed)", &quad, &classes, t0.elapsed().as_secs_f64(), &mut table, paper[1].1);
    }

    // RFF at D = 50, 200, 500, 1000.
    for (idx, dd) in [50usize, 200, 500, 1000].iter().enumerate() {
        let t0 = std::time::Instant::now();
        let mut seed_rng = Rng::seeded(100 + *dd as u64);
        let rff = RffSampler::new(&classes, *dd, TAU, &mut seed_rng);
        bench_method(
            &b,
            &format!("Rff (D={dd})"),
            &rff,
            &classes,
            t0.elapsed().as_secs_f64(),
            &mut table,
            paper[2 + idx].1,
        );
    }

    println!("\n{}", table.render());
}

fn main() {
    bench_header("T2", "sampling wall time (paper Table 2)");
    run_for_n(
        10_000,
        &[
            ("Exp", "1.4 ms"),
            ("Quadratic", "6.5 ms"),
            ("Rff50", "0.5 ms"),
            ("Rff200", "0.6 ms"),
            ("Rff500", "1.2 ms"),
            ("Rff1000", "1.4 ms"),
        ],
    );
    if std::env::var("RFSM_QUICK").is_err() {
        run_for_n(
            500_000,
            &[
                ("Exp", "32.3 ms"),
                ("Quadratic", "8.2 ms"),
                ("Rff50", "1.6 ms"),
                ("Rff200", "1.7 ms"),
                ("Rff500", "2.0 ms"),
                ("Rff1000", "2.4 ms"),
            ],
        );
    } else {
        println!("(RFSM_QUICK set: skipping n = 500,000)");
    }
    println!(
        "shape check: Exp ≈ linear in n; Rff ≈ flat in n, mild in D; \
         Quadratic ≫ Rff at both n."
    );
}
