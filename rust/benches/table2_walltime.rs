//! Table 2 reproduction: wall time to compute the sampled-softmax loss
//! for one batch (batch = 10, m = 10, d = 64) under each model-dependent
//! sampling method, at n = 10,000 and n = 500,000.
//!
//! Paper rows (ms): n=10k — EXP 1.4, QUADRATIC 6.5, RFF(50/200/500/1000)
//! 0.5/0.6/1.2/1.4; n=500k — EXP 32.3, QUADRATIC 8.2, RFF 1.6/1.7/2.0/2.4.
//! Shape to reproduce: EXP grows linearly in n and loses badly at 500k;
//! RFF stays ~flat in n (log n) and scales mildly with D; QUADRATIC sits
//! well above RFF at the same n (its D is d² = 4096).
//!
//! `RFSM_QUICK=1` limits to n = 10,000 (the 500k tree builds take ~1 min
//! on this single-core box and are reported separately as build time).
//!
//! The second half is the **fused-step A/B** (ISSUE 9): one native LM
//! train step — gather → LSTM forward → one-pass sampled loss/grad →
//! BPTT backward — through the fused kernels with reusable scratch vs
//! the composed stage-by-stage baseline (`runtime::native::composed`,
//! the retired artifact pipeline's shape: fresh buffers per stage, the
//! full `bsz×(1+m)` logit matrix materialized). Emits a
//! `BENCH {json}` `train_step_fused` record (total + per-stage times,
//! `speedup` = composed/fused) gated in CI via
//! `bench-check --require-fused-speedup`.
//!
//! Run: `cargo bench --bench table2_walltime`
//! `--smoke` (CI bench-smoke job) runs only the fused-step A/B at small
//! shapes so the record exists in seconds; numbers are not comparable
//! to full runs (`"smoke": true`).

use rfsoftmax::benchkit::{bench_header, black_box, Bencher};
use rfsoftmax::json::Json;
use rfsoftmax::linalg::{simd, unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::runtime::native::{
    composed, gather_rows_into, FusedLoss, LmStep,
};
use rfsoftmax::sampler::{
    BucketKernelSampler, ExactSoftmaxSampler, RffSampler, Sampler,
};
use rfsoftmax::softmax::sampled_softmax_loss;
use rfsoftmax::tables::Table;
use std::time::Duration;

const BATCH: usize = 10;
const M: usize = 10;
const D_EMB: usize = 64;
const TAU: f32 = 4.0;

/// One "compute sampled softmax loss" unit, as the paper times it:
/// draw m negatives for the batch query, adjust, evaluate the loss for
/// every example in the batch.
fn loss_once(
    sampler: &dyn Sampler,
    queries: &[Vec<f32>],
    classes: &Matrix,
    rng: &mut Rng,
) -> f64 {
    let q0 = &queries[0];
    let draw = sampler.sample(q0, M, rng);
    let mut acc = 0.0;
    for h in queries {
        let o_t = (TAU * rfsoftmax::linalg::dot(h, classes.row(0))) as f64;
        let negs: Vec<f64> = draw
            .ids
            .iter()
            .map(|&i| {
                (TAU * rfsoftmax::linalg::dot(h, classes.row(i as usize)))
                    as f64
            })
            .collect();
        acc += sampled_softmax_loss(o_t, &negs, &draw.probs).loss;
    }
    acc
}

fn bench_method(
    b: &Bencher,
    name: &str,
    sampler: &dyn Sampler,
    classes: &Matrix,
    build_secs: f64,
    table: &mut Table,
    paper: &str,
) {
    let mut rng = Rng::seeded(77);
    let queries: Vec<Vec<f32>> =
        (0..BATCH).map(|_| unit_vector(&mut rng, D_EMB)).collect();
    let mut sample_rng = Rng::seeded(78);
    let s = b.run(name, || {
        black_box(loss_once(sampler, &queries, classes, &mut sample_rng))
    });
    println!("  {}", s.report());
    table.row(&[
        name.to_string(),
        format!("{:.2} ms", s.mean() * 1e3),
        paper.to_string(),
        format!("{build_secs:.1} s"),
    ]);
}

fn run_for_n(n: usize, paper: &[(&str, &str)]) {
    println!("\n-- n = {n} --");
    let mut rng = Rng::seeded(7);
    let classes = Matrix::randn(&mut rng, n, D_EMB).l2_normalized_rows();
    let b = Bencher {
        warmup: Duration::from_millis(100),
        budget: Duration::from_millis(800),
        samples: 10,
    };
    let mut table = Table::new(
        &format!("Table 2 — sampled-softmax loss wall time, n={n} (batch=10, m=10, d=64)"),
        &["Method", "wall", "paper", "build"],
    );

    // EXP: exact softmax sampling, O(dn).
    let t0 = std::time::Instant::now();
    let exact = ExactSoftmaxSampler::new(&classes, TAU);
    bench_method(&b, "Exp", &exact, &classes, t0.elapsed().as_secs_f64(), &mut table, paper[0].1);

    // QUADRATIC: kernel tree with D = d²+1 (bucketed at large n).
    let t0 = std::time::Instant::now();
    if n <= 100_000 {
        let quad = rfsoftmax::sampler::QuadraticSampler::new(&classes, 100.0, 1.0);
        bench_method(&b, "Quadratic", &quad, &classes, t0.elapsed().as_secs_f64(), &mut table, paper[1].1);
    } else {
        let map = rfsoftmax::featmap::QuadraticMap::new(D_EMB, 100.0, 1.0);
        let quad = BucketKernelSampler::with_map(&classes, map, 1024, "quadratic");
        bench_method(&b, "Quadratic (bucketed)", &quad, &classes, t0.elapsed().as_secs_f64(), &mut table, paper[1].1);
    }

    // RFF at D = 50, 200, 500, 1000.
    for (idx, dd) in [50usize, 200, 500, 1000].iter().enumerate() {
        let t0 = std::time::Instant::now();
        let mut seed_rng = Rng::seeded(100 + *dd as u64);
        let rff = RffSampler::new(&classes, *dd, TAU, &mut seed_rng);
        bench_method(
            &b,
            &format!("Rff (D={dd})"),
            &rff,
            &classes,
            t0.elapsed().as_secs_f64(),
            &mut table,
            paper[2 + idx].1,
        );
    }

    println!("\n{}", table.render());
}

/// Fused-vs-composed A/B over one complete LM train step's compute
/// (sampling excluded: both sides consume the same pre-drawn negative
/// pack, so the delta is pure execution — fusion + scratch reuse +
/// fan-out against staged gemms with per-stage allocations).
fn bench_fused_step(smoke: bool) {
    let (bsz, l, d, h, m, n) = if smoke {
        (16usize, 8usize, 32usize, 64usize, 32usize, 4_000usize)
    } else {
        (32, 16, 64, 128, 64, 10_000)
    };
    let workers = rfsoftmax::exec::recommended_workers();
    println!(
        "\n-- fused vs composed LM train step \
         (b={bsz} l={l} d={d} h={h} m={m} n={n} workers={workers}) --"
    );
    let b = if smoke {
        Bencher {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(80),
            samples: 3,
        }
    } else {
        Bencher {
            warmup: Duration::from_millis(150),
            budget: Duration::from_millis(900),
            samples: 10,
        }
    };

    let mut rng = Rng::seeded(21);
    let emb = Matrix::randn(&mut rng, n, d).into_vec();
    let wx = Matrix::randn(&mut rng, d, 4 * h).into_vec();
    let wh = Matrix::randn(&mut rng, h, 4 * h).into_vec();
    let bias = vec![0.0f32; 4 * h];
    let proj = Matrix::randn(&mut rng, h, d).into_vec();
    let cls = Matrix::randn(&mut rng, n, d).l2_normalized_rows().into_vec();
    let contexts: Vec<u32> =
        (0..bsz * l).map(|_| rng.index(n) as u32).collect();
    let targets: Vec<u32> = (0..bsz).map(|_| rng.index(n) as u32).collect();
    let negs: Vec<u32> = (0..m).map(|_| rng.index(n) as u32).collect();
    // adjust = log(m·q) for a synthetic proposal q ∈ (0, 1/n].
    let adjust: Vec<f32> = (0..m)
        .map(|_| ((m as f64) * rng.f64_open() / n as f64).ln() as f32)
        .collect();
    let mut mask = vec![1.0f32; bsz * m];
    for (r, &t) in targets.iter().enumerate() {
        for (j, &g) in negs.iter().enumerate() {
            if g == t {
                mask[r * m + j] = 0.0;
            }
        }
    }

    // Fused path: persistent kernels + scratch, as LmTrainer runs it.
    let mut lm = LmStep::new(workers);
    let mut fused = FusedLoss::new(workers);
    let mut tgt_buf: Vec<f32> = Vec::new();
    let mut neg_buf: Vec<f32> = Vec::new();
    let s_fused = b.run("fused one-pass step", || {
        lm.begin(bsz, l, d, h);
        lm.load_rows(&emb, &contexts);
        lm.forward(&wx, &wh, &bias, &proj);
        gather_rows_into(&cls, d, &targets, &mut tgt_buf);
        gather_rows_into(&cls, d, &negs, &mut neg_buf);
        let loss = fused.run(
            &mut lm.u,
            &mut tgt_buf,
            &mut neg_buf,
            &adjust,
            &mask,
            TAU,
            false,
        );
        lm.backward(&wx, &wh, &proj, &fused.d_q);
        black_box(loss)
    });
    println!("  {}", s_fused.report());
    // Per-stage breakdown (state from the total runs above stays valid).
    let s_fwd = b.run("  stage: gather+forward", || {
        lm.begin(bsz, l, d, h);
        lm.load_rows(&emb, &contexts);
        lm.forward(&wx, &wh, &bias, &proj);
        black_box(lm.u.row(0)[0])
    });
    let s_loss = b.run("  stage: fused loss/grad", || {
        gather_rows_into(&cls, d, &targets, &mut tgt_buf);
        gather_rows_into(&cls, d, &negs, &mut neg_buf);
        black_box(fused.run(
            &mut lm.u,
            &mut tgt_buf,
            &mut neg_buf,
            &adjust,
            &mask,
            TAU,
            false,
        ))
    });
    let s_bwd = b.run("  stage: backward", || {
        lm.backward(&wx, &wh, &proj, &fused.d_q);
        black_box(lm.dwx[0])
    });
    println!("  {}", s_fwd.report());
    println!("  {}", s_loss.report());
    println!("  {}", s_bwd.report());

    // Composed baseline: same math, staged with fresh buffers per call.
    let gather = |table: &[f32], ids: &[u32]| -> Vec<f32> {
        let mut out = Vec::with_capacity(ids.len() * d);
        for &id in ids {
            let s = id as usize * d;
            out.extend_from_slice(&table[s..s + d]);
        }
        out
    };
    let s_comp = b.run("composed stage-by-stage step", || {
        let x = gather(&emb, &contexts);
        let st = composed::lm_forward(&x, bsz, l, d, h, &wx, &wh, &bias, &proj);
        let tgt = gather(&cls, &targets);
        let neg = gather(&cls, &negs);
        let out = composed::sampled_loss_grad(
            &st.u, &tgt, &neg, &adjust, &mask, TAU, false,
        );
        let g = composed::lm_backward(
            &st, &x, bsz, l, d, h, &wx, &wh, &proj, &out.d_q,
        );
        black_box(out.loss + g.dwx[0])
    });
    println!("  {}", s_comp.report());

    let fused_sps = 1.0 / s_fused.mean();
    let comp_sps = 1.0 / s_comp.mean();
    let speedup = s_comp.mean() / s_fused.mean();
    println!(
        "  fused {:.3} ms vs composed {:.3} ms — {speedup:.2}×",
        s_fused.mean() * 1e3,
        s_comp.mean() * 1e3,
    );
    let record = Json::obj(vec![
        ("bench", Json::from("train_step_fused")),
        ("task", Json::from("lm")),
        ("b", Json::from(bsz)),
        ("l", Json::from(l)),
        ("d", Json::from(d)),
        ("h", Json::from(h)),
        ("m", Json::from(m)),
        ("n", Json::from(n)),
        ("workers", Json::from(workers)),
        ("fused_ms", Json::from(s_fused.mean() * 1e3)),
        ("composed_ms", Json::from(s_comp.mean() * 1e3)),
        ("fwd_ms", Json::from(s_fwd.mean() * 1e3)),
        ("loss_ms", Json::from(s_loss.mean() * 1e3)),
        ("bwd_ms", Json::from(s_bwd.mean() * 1e3)),
        ("fused_steps_per_sec", Json::from(fused_sps)),
        ("composed_steps_per_sec", Json::from(comp_sps)),
        ("speedup", Json::from(speedup)),
        ("simd", Json::from(simd::tier_name())),
        ("smoke", Json::from(smoke)),
    ]);
    println!("BENCH {record}");
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    bench_header(
        "T2",
        if smoke {
            "fused train step A/B (SMOKE: paper table skipped)"
        } else {
            "sampling wall time (paper Table 2) + fused train step A/B"
        },
    );
    if !smoke {
        run_for_n(
            10_000,
            &[
                ("Exp", "1.4 ms"),
                ("Quadratic", "6.5 ms"),
                ("Rff50", "0.5 ms"),
                ("Rff200", "0.6 ms"),
                ("Rff500", "1.2 ms"),
                ("Rff1000", "1.4 ms"),
            ],
        );
        if std::env::var("RFSM_QUICK").is_err() {
            run_for_n(
                500_000,
                &[
                    ("Exp", "32.3 ms"),
                    ("Quadratic", "8.2 ms"),
                    ("Rff50", "1.6 ms"),
                    ("Rff200", "1.7 ms"),
                    ("Rff500", "2.0 ms"),
                    ("Rff1000", "2.4 ms"),
                ],
            );
        } else {
            println!("(RFSM_QUICK set: skipping n = 500,000)");
        }
        println!(
            "shape check: Exp ≈ linear in n; Rff ≈ flat in n, mild in D; \
             Quadratic ≫ Rff at both n."
        );
    }
    bench_fused_step(smoke);
}
