//! Theorem-1 empirics (our validation experiment X1): Monte-Carlo
//! gradient bias `‖E[∇L′] − ∇L‖` and the eq.-12 distribution diagnostics
//! per sampler, swept over m and (for RFF) over D.
//!
//! Expected ordering (Theorem 1 + Corollary 1): EXP ≈ 0 and UB₁ = 0;
//! RFF bias decreasing in D, approaching EXP; UNIFORM/log-uniform clearly
//! worse; every bias shrinking in m.
//!
//! Run: `cargo bench --bench bias_ablation`

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::bias::{empirical_bias, theorem_diagnostics};
use rfsoftmax::linalg::{l2_normalize, unit_vector, Matrix};
use rfsoftmax::rng::Rng;
use rfsoftmax::sampler::{
    ExactSoftmaxSampler, LogUniformSampler, QuadraticSampler, RffSampler,
    Sampler, UniformSampler,
};
use rfsoftmax::tables::{fmt_sci, Table};

fn main() {
    bench_header("X1", "gradient-bias ablation (Theorem 1 empirics)");
    let n = 100;
    let d = 16;
    let tau = 8.0f32;
    let trials: usize = std::env::var("RFSM_BIAS_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4000);

    let mut rng = Rng::seeded(5);
    let mut classes = Matrix::randn(&mut rng, n, d).l2_normalized_rows();
    let h = unit_vector(&mut rng, d);
    for i in 0..3 {
        let row = classes.row_mut(i);
        for (r, &hv) in row.iter_mut().zip(h.iter()) {
            *r = hv + 0.1 * (i as f32 + 1.0);
        }
        l2_normalize(row);
    }
    let target = 50;

    let samplers: Vec<(String, Box<dyn Sampler>)> = vec![
        ("exp".into(), Box::new(ExactSoftmaxSampler::new(&classes, tau))),
        (
            "rff D=64".into(),
            Box::new(RffSampler::new(&classes, 64, tau, &mut rng)),
        ),
        (
            "rff D=512".into(),
            Box::new(RffSampler::new(&classes, 512, tau, &mut rng)),
        ),
        (
            "rff D=4096".into(),
            Box::new(RffSampler::new(&classes, 4096, tau, &mut rng)),
        ),
        (
            "quadratic".into(),
            Box::new(QuadraticSampler::new(&classes, 100.0, 1.0)),
        ),
        ("uniform".into(), Box::new(UniformSampler::new(n))),
        ("loguniform".into(), Box::new(LogUniformSampler::new(n))),
    ];

    for m in [5usize, 20, 100] {
        let mut t = Table::new(
            &format!(
                "Gradient bias (logit space), n={n}, τ={tau}, m={m}, \
                 {trials} MC trials"
            ),
            &["sampler", "|bias|₂", "|bias|∞", "MC-se", "UB₁", "LB-gap"],
        );
        for (name, s) in &samplers {
            let est = empirical_bias(
                &classes, &h, target, tau, s.as_ref(), m, trials, &mut rng,
            );
            let diag = theorem_diagnostics(
                &classes, &h, target, tau, s.as_ref(), m,
            );
            t.row(&[
                name.clone(),
                fmt_sci(est.l2),
                fmt_sci(est.linf),
                fmt_sci(est.max_se),
                fmt_sci(diag.ub1),
                fmt_sci(diag.max_lb_gap / diag.floor.sqrt()),
            ]);
        }
        println!("{}", t.render());
    }
    println!(
        "shape check: bias(exp) ≈ MC noise; bias(rff) ↓ in D → exp; \
         uniform/loguniform ≫ rff; all ↓ in m."
    );
}
