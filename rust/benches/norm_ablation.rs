//! §4.2 normalization ablation (the paper's "Normalized vs. unnormalized
//! embeddings" paragraph): train the FULL-softmax model with and without
//! L2-normalized embeddings on the PTB-scale corpus and the AmazonCat
//! stand-in.
//!
//! Paper result: PTB valid ppl 120 (normalized) vs 126 (unnormalized)
//! after 10 epochs; AmazonCat P@1 87% for both. Shape: normalization never
//! hurts, helps on the LM.
//!
//! Run: `cargo bench --bench norm_ablation`

use anyhow::Result;
use rfsoftmax::benchkit::bench_header;
use rfsoftmax::coordinator::harness::{
    bench_steps, config_from, corpus_config,
};
use rfsoftmax::coordinator::{Trainer, TrainerBuilder};
use rfsoftmax::runtime::Runtime;
use rfsoftmax::tables::Table;

fn main() -> Result<()> {
    bench_header("N1", "normalized vs unnormalized embeddings (paper §4.2)");
    let runtime = Runtime::native();
    let steps = bench_steps(400);

    // --- LM (PTB-scale) -------------------------------------------------
    let mut t = Table::new(
        "PTB-scale FULL softmax: normalized vs unnormalized",
        &["variant", "valid ppl", "paper"],
    );
    for (unnorm, label, paper) in
        [(false, "normalized", "120"), (true, "unnormalized", "126")]
    {
        let cfg = config_from(&[
            ("sampler.kind", "full".into()),
            ("train.steps", steps.to_string()),
            ("train.eval_every", steps.to_string()),
            ("train.eval_batches", "6".into()),
            ("train.lr", "0.5".into()),
            ("data.train_size", "120000".into()),
            ("data.valid_size", "10000".into()),
        ])?;
        let mut trainer = TrainerBuilder::new(&runtime, "ptb", cfg)
            .unnormalized(unnorm)
            .build()?;
        let r = trainer.run()?;
        println!("  [{label}] ppl {:.1}", r.final_metric);
        t.row(&[
            label.into(),
            format!("{:.1}", r.final_metric),
            paper.into(),
        ]);
    }
    println!("\n{}", t.render());

    // --- XC (AmazonCat stand-in) ----------------------------------------
    let mut t2 = Table::new(
        "AmazonCat-13K-shape FULL softmax: normalized vs unnormalized",
        &["variant", "P@1", "paper"],
    );
    for (unnorm, label) in [(false, "normalized"), (true, "unnormalized")] {
        let cfg = corpus_config(
            "xc_amazon",
            &[
                ("sampler.kind", "full".into()),
                ("train.steps", (steps * 3).to_string()),
                ("train.eval_every", (steps * 3).to_string()),
                ("train.eval_batches", "8".into()),
                ("train.lr", "1.0".into()),
                ("data.train_size", "12000".into()),
                ("data.valid_size", "1024".into()),
                ("data.noise", "0.15".into()),
            ],
        )?;
        let mut trainer = TrainerBuilder::new(&runtime, "xc_amazon", cfg)
            .unnormalized(unnorm)
            .build()?;
        trainer.run()?;
        let (p1, _, _) = match &mut trainer {
            Trainer::Xc(x) => x.final_precisions()?,
            _ => unreachable!(),
        };
        println!("  [{label}] P@1 {p1:.3}");
        t2.row(&[label.into(), format!("{p1:.2}"), "0.87".into()]);
    }
    println!("\n{}", t2.render());
    println!("shape check: normalized ≤ unnormalized ppl on the LM; P@1 ≈ equal on XC.");
    Ok(())
}
