//! Figure 4 reproduction: RF-softmax vs baselines on the Bnews-scale
//! corpus (n = 64,000, m = 100), validation perplexity vs training
//! progress, including the D = 2048 vs 8192 comparison.
//!
//! Paper shape: RFF(D=2048) at par with QUADRATIC, RFF(D=8192) better;
//! both ≫ UNIFORM; EXP best of the sampled methods.
//!
//! Heavier than the PTB benches (n = 64k eval, larger model); scale with
//! RFSM_BENCH_STEPS.
//!
//! Run: `cargo bench --bench fig4_bnews_baselines`

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::coordinator::harness::{
    bench_steps, corpus_config, curves_table, train_once,
};
use rfsoftmax::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bench_header("F4", "sampler comparison on Bnews (paper Figure 4)");
    let runtime = Runtime::native();
    let steps = bench_steps(150);
    let eval_every = (steps / 3).max(1);

    let variants: Vec<(&str, Vec<(&str, String)>)> = vec![
        ("EXP", vec![("sampler.kind", "exact".into())]),
        // SORF features: the classic RFF map would spend ~1 min per run
        // just building φ for 64k classes on this single-core box; SORF's
        // O(D log d) map keeps the build tractable with the same kernel
        // (paper §3.2 explicitly endorses SORF for this).
        (
            "RFF D=2048",
            vec![
                ("sampler.kind", "rff".into()),
                ("sampler.dim", "2048".into()),
                ("sampler.feature_map", "sorf".into()),
            ],
        ),
        (
            "RFF D=8192",
            vec![
                ("sampler.kind", "rff".into()),
                ("sampler.dim", "8192".into()),
                ("sampler.feature_map", "sorf".into()),
            ],
        ),
        ("QUADRATIC", vec![("sampler.kind", "quadratic".into())]),
        ("UNIFORM", vec![("sampler.kind", "uniform".into())]),
    ];

    let mut runs = Vec::new();
    for (label, extra) in variants {
        let mut pairs: Vec<(&str, String)> = vec![
            ("sampler.num_negatives", "100".into()),
            ("sampler.T", "0.5".into()),
            ("train.steps", steps.to_string()),
            ("train.eval_every", eval_every.to_string()),
            ("train.eval_batches", "2".into()),
            ("train.lr", "0.5".into()),
            ("data.train_size", "100000".into()),
            ("data.valid_size", "8000".into()),
        ];
        pairs.extend(extra);
        let cfg = corpus_config("bnews", &pairs)?;
        let r = train_once(&runtime, "bnews", label, cfg)?;
        runs.push((label.to_string(), r));
    }

    println!(
        "\n{}",
        curves_table(
            "Figure 4 — validation perplexity vs step on Bnews-scale \
             (n=64k, m=100)",
            &runs
        )
        .render()
    );
    println!(
        "shape check: RFF(8192) ≤ RFF(2048) ≈ QUADRATIC; UNIFORM worst; \
         EXP best."
    );
    Ok(())
}
