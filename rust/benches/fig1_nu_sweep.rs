//! Figure 1 reproduction: validation perplexity on the PTB-scale corpus
//! for RF-softmax with varying Gaussian-kernel temperature T = 1/√ν
//! (D = 1024, m = 100).
//!
//! Paper shape: the best curve sits at T = 0.5 (ν < τ, the bias/variance
//! trade-off of §3.3); T too large (≈1.0, weak kernel) and T too small
//! (= 0.3 = the softmax temperature, high variance) are both worse.
//!
//! Run: `cargo bench --bench fig1_nu_sweep` (RFSM_BENCH_STEPS scales it)

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::coordinator::harness::{
    bench_steps, config_from, curves_table, train_once,
};
use rfsoftmax::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bench_header("F1", "RF-softmax ν sweep on PTB (paper Figure 1)");
    let runtime = Runtime::native();
    let steps = bench_steps(400);
    let eval_every = (steps / 4).max(1);

    let mut runs = Vec::new();
    for t in ["0.3", "0.4", "0.5", "0.7", "1.0"] {
        let cfg = config_from(&[
            ("sampler.kind", "rff".into()),
            ("sampler.num_negatives", "100".into()),
            ("sampler.dim", "1024".into()),
            ("sampler.T", t.into()),
            ("train.steps", steps.to_string()),
            ("train.eval_every", eval_every.to_string()),
            ("train.eval_batches", "4".into()),
            ("train.lr", "0.5".into()),
            ("data.train_size", "120000".into()),
            ("data.valid_size", "10000".into()),
        ])?;
        let r = train_once(&runtime, "ptb", &format!("T={t}"), cfg)?;
        runs.push((format!("T={t}"), r));
    }

    println!(
        "\n{}",
        curves_table(
            "Figure 1 — validation perplexity vs step, varying T = 1/√ν \
             (PTB-scale, D=1024, m=100)",
            &runs
        )
        .render()
    );
    let best = runs
        .iter()
        .min_by(|a, b| {
            a.1.final_metric.partial_cmp(&b.1.final_metric).unwrap()
        })
        .unwrap();
    println!(
        "best T: {} (paper: T = 0.5; some ν < τ must win over T = 0.3)",
        best.0
    );
    Ok(())
}
