//! Figure 3 reproduction: RF-softmax vs baselines on the PTB-scale corpus
//! (m = 100, validation perplexity vs training progress).
//!
//! Paper shape: EXP ≈ FULL (sampling from the exact softmax loses almost
//! nothing); RFF (D=1024) close behind and clearly better than QUADRATIC
//! and UNIFORM.
//!
//! Run: `cargo bench --bench fig3_ptb_baselines`

use rfsoftmax::benchkit::bench_header;
use rfsoftmax::coordinator::harness::{
    bench_steps, config_from, curves_table, train_once,
};
use rfsoftmax::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    bench_header("F3", "sampler comparison on PTB (paper Figure 3)");
    let runtime = Runtime::native();
    let steps = bench_steps(400);
    let eval_every = (steps / 4).max(1);

    let mut runs = Vec::new();
    for kind in ["full", "exact", "rff", "quadratic", "uniform"] {
        let cfg = config_from(&[
            ("sampler.kind", kind.into()),
            ("sampler.num_negatives", "100".into()),
            ("sampler.dim", "2048".into()),
            ("sampler.T", "0.5".into()),
            ("train.steps", steps.to_string()),
            ("train.eval_every", eval_every.to_string()),
            ("train.eval_batches", "4".into()),
            ("train.lr", "0.5".into()),
            ("data.train_size", "120000".into()),
            ("data.valid_size", "10000".into()),
        ])?;
        let label = match kind {
            "exact" => "EXP",
            k => k,
        };
        let r = train_once(&runtime, "ptb", label, cfg)?;
        runs.push((label.to_uppercase(), r));
    }

    println!(
        "\n{}",
        curves_table(
            "Figure 3 — validation perplexity vs step on PTB-scale \
             (m=100, RFF D=2048)",
            &runs
        )
        .render()
    );
    println!(
        "shape check: EXP ≈ FULL; RFF close to EXP; RFF < QUADRATIC; \
         UNIFORM worst."
    );
    Ok(())
}
